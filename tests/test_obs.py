"""repro.obs: metrics registry, span tracer, compile accounting, and the
instrumented stack.

Contracts under test:

1. *Registry semantics*: counters/gauges/histograms with label sets;
   disabled instruments are no-ops; histograms never return NaN
   percentiles; snapshots / Prometheus text / JSONL export round-trip.
2. *Tracer*: spans nest correctly (depth metadata + containment), the
   Chrome-trace file round-trips through ``json.load`` with the expected
   event schema, and disabled mode adds no measurable overhead
   (guard-banded timing).
3. *Compile accounting*: ``instrument_jit`` ticks exactly one counter per
   compiled variant, labelled with the offending shape key; cached calls
   add nothing.
4. *Instrumented stack*: plan-cache eviction ticks the new ``evictions``
   counter without changing results; autotune lookups record outcomes;
   dispatch entries count calls.
5. *Regression gate* (``repro.obs.baseline``): flat-record extraction,
   median/MAD aggregation, verdict direction for higher/lower-is-better
   metrics, baseline round-trip + schema guard, and the
   ``benchmarks/run.py`` CLI end-to-end via the env-steered fixture suite
   (update → clean compare exit 0 → injected regression exit 2 → crash
   exit 1).
6. *SLOs* (``repro.obs.slo``): all three evaluation surfaces (value
   dicts, registry snapshots with group_by, JSONL logs with burn-rate),
   plus the wired health endpoints — ``SessionStore.health()`` flags a
   seeded staleness breach and ``train_loop`` warns/aborts on its
   trailing-window bundle.
7. *Flight recorder* (``repro.obs.flight``): bounded ring, Chrome-trace
   dump contents (spans + retrace keys + exception), single-dump marker
   across nested boundaries, and a crashing ``train_loop`` leaving a
   dump behind.  Plus the bounded trace ring / metric-cardinality guard
   satellites.
"""
import dataclasses
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.kernels import ops
from repro.obs import baseline


@pytest.fixture(autouse=True)
def clean_registry():
    """Each test starts disabled with zeroed instruments and no active
    trace (module import may have inherited env state)."""
    obs.disable()
    obs.reset()
    if obs.trace_active():
        obs.TRACER._active = False
    obs.TRACER.clear()
    yield
    obs.disable()
    obs.reset()
    obs.TRACER._active = False
    obs.TRACER.clear()


# ---------------------------------------------------------------------------
# 1. registry
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_label_sets():
    with obs.enabled_scope():
        c = obs.counter("t_requests_total", "x", ("op",))
        c.inc(op="a")
        c.inc(2, op="a")
        c.inc(op="b")
        assert c.value(op="a") == 3.0
        assert c.value(op="b") == 1.0
        assert c.total() == 4.0

        g = obs.gauge("t_depth", "x", ("pool",))
        g.set(7, pool="p")
        g.add(-2, pool="p")
        assert g.value(pool="p") == 5.0

        h = obs.histogram("t_lat_seconds", "x", ("site",))
        for v in (1e-4, 2e-4, 5e-2):
            h.observe(v, site="s")
        assert h.count(site="s") == 3
        assert 0 < h.percentile(50, site="s") < 5e-2


def test_disabled_instruments_are_noops():
    c = obs.counter("t_off_total", "x", ("op",))
    h = obs.histogram("t_off_seconds", "x")
    g = obs.gauge("t_off_gauge", "x")
    c.inc(op="a")
    h.observe(1.0)
    g.set(3.0)
    assert c.total() == 0.0
    assert h.count() == 0
    assert g.value() == 0.0


def test_histogram_empty_percentile_is_zero_not_nan():
    with obs.enabled_scope():
        h = obs.histogram("t_empty_seconds", "x", ("site",))
        p = h.percentile(50, site="never_observed")
        assert p == 0.0 and not np.isnan(p)


def test_metric_type_conflict_raises():
    with obs.enabled_scope():
        obs.counter("t_conflict", "x", ("a",))
        with pytest.raises(ValueError, match="already registered"):
            obs.gauge("t_conflict", "x", ("a",))
        with pytest.raises(ValueError, match="already registered"):
            obs.counter("t_conflict", "x", ("b",))


def test_missing_label_raises():
    with obs.enabled_scope():
        c = obs.counter("t_labels_total", "x", ("op", "backend"))
        with pytest.raises(ValueError, match="missing"):
            c.inc(op="a")


def test_snapshot_prometheus_and_jsonl_roundtrip(tmp_path):
    with obs.enabled_scope():
        obs.counter("t_snap_total", "help text", ("op",)).inc(3, op="sig")
        obs.histogram("t_snap_seconds", "h", ()).observe(0.01)
        snap = obs.snapshot()
        assert snap["metrics"]["t_snap_total"]["type"] == "counter"
        assert snap["metrics"]["t_snap_total"]["values"][0] == {
            "labels": {"op": "sig"}, "value": 3.0}

        p = obs.write_snapshot(str(tmp_path / "snap.json"))
        assert json.load(open(p))["metrics"]["t_snap_total"]["values"]

        jl = str(tmp_path / "snap.jsonl")
        obs.append_jsonl(jl, extra={"suite": "x"})
        obs.append_jsonl(jl)
        lines = [json.loads(ln) for ln in open(jl)]
        assert len(lines) == 2 and lines[0]["suite"] == "x"

        text = obs.to_prometheus()
        assert "# TYPE t_snap_total counter" in text
        assert 't_snap_total{op="sig"} 3.0' in text
        assert "t_snap_seconds_bucket" in text
        assert "t_snap_seconds_count 1" in text


def test_collector_runs_at_snapshot_time():
    calls = []
    reg = obs.Registry(enabled=True)
    reg.register_collector(lambda r: calls.append(1) or r.gauge(
        "t_pulled", "x").set(42.0))
    snap = reg.snapshot()
    assert calls == [1]
    assert snap["metrics"]["t_pulled"]["values"][0]["value"] == 42.0


def test_jsonl_sink_appends_per_call(tmp_path):
    sink = obs.jsonl_sink(str(tmp_path / "run.jsonl"))
    sink(0, {"loss": 1.5})
    sink(10, {"loss": 0.5, "straggler": True})
    lines = [json.loads(ln) for ln in open(sink.path)]
    assert [ln["step"] for ln in lines] == [0, 10]
    assert lines[1]["straggler"] is True


# ---------------------------------------------------------------------------
# 2. tracer
# ---------------------------------------------------------------------------

def test_spans_nest_and_chrome_trace_roundtrips(tmp_path):
    path = str(tmp_path / "trace.json")
    with obs.trace_scope(path):
        with obs.span("outer", layer="serve"):
            time.sleep(0.002)
            with obs.span("inner"):
                time.sleep(0.001)
        obs.instant("marker", n=1)
    doc = json.load(open(path))
    assert set(doc) >= {"traceEvents", "displayTimeUnit"}
    evs = {e["name"]: e for e in doc["traceEvents"]}
    assert {"outer", "inner", "marker"} <= set(evs)
    # schema: complete events carry ph/ts/dur/pid/tid/args
    for name in ("outer", "inner"):
        e = evs[name]
        assert e["ph"] == "X"
        assert {"ts", "dur", "pid", "tid", "args"} <= set(e)
    assert evs["marker"]["ph"] == "i"
    # nesting: depth metadata + interval containment on one track
    assert evs["outer"]["args"]["depth"] == 0
    assert evs["inner"]["args"]["depth"] == 1
    assert evs["outer"]["ts"] <= evs["inner"]["ts"]
    assert (evs["inner"]["ts"] + evs["inner"]["dur"]
            <= evs["outer"]["ts"] + evs["outer"]["dur"] + 1.0)
    assert evs["outer"]["args"]["layer"] == "serve"


def test_spans_nest_under_jit_boundaries(tmp_path):
    """Spans opened around and inside (at trace time) a jit call keep
    consistent nesting — the inner span is emitted at trace time only."""
    path = str(tmp_path / "trace.json")

    def f(x):
        with obs.span("jit.body"):
            return x * 2

    jf = jax.jit(f)
    x = jnp.ones(4)
    with obs.trace_scope(path):
        with obs.span("call.outer"):
            jf(x).block_until_ready()      # compiles: body span emitted
        with obs.span("call.cached"):
            jf(x).block_until_ready()      # cached: no new body span
    evs = json.load(open(path))["traceEvents"]
    body = [e for e in evs if e["name"] == "jit.body"]
    outer = [e for e in evs if e["name"] == "call.outer"]
    assert len(body) == 1 and len(outer) == 1
    assert body[0]["args"]["depth"] == outer[0]["args"]["depth"] + 1


def test_disabled_tracing_adds_no_measurable_overhead():
    """Guard-banded absolute bound: a disabled span costs well under 10us
    per entry (typical ~0.5us — one flag check + the null-span singleton).
    The generous band absorbs CI scheduling noise."""
    N = 20_000

    def instrumented():
        t0 = time.perf_counter()
        acc = 0
        for i in range(N):
            with obs.span("hot"):
                acc += i
        return time.perf_counter() - t0

    assert not obs.trace_active() and not obs.enabled()
    instrumented()   # warm
    per_span = min(instrumented() for _ in range(5)) / N
    assert per_span < 10e-6, f"{per_span * 1e6:.2f}us per disabled span"


def test_null_span_supports_set():
    s = obs.span("inactive", a=1)
    assert s.set(b=2) is s
    with s:
        pass


# ---------------------------------------------------------------------------
# 3. compile accounting
# ---------------------------------------------------------------------------

def test_shape_key_describes_arrays_and_pytrees():
    x = jnp.zeros((4, 10, 3), jnp.float32)
    key = obs.shape_key(x, depth=3, split=None)
    assert "f32[4,10,3]" in key and "depth=3" in key

    key2 = obs.shape_key({"a": x, "b": [x, x]})
    assert "a:f32[4,10,3]" in key2


def test_instrument_jit_counts_one_trace_per_variant():
    with obs.enabled_scope():
        calls = []

        def f(x):
            calls.append(1)
            return x + 1

        jf = obs.instrument_jit(f, site="t_site")
        x4 = jnp.zeros(4)
        x8 = jnp.zeros(8)
        jf(x4); jf(x4); jf(x4)          # one compile, three calls
        jf(x8)                           # second variant
        c = obs.REGISTRY.get(obs.TRACE_COUNTER_NAME)
        by_shape = {row["labels"]["shapes"]: row["value"]
                    for row in c._values_list()
                    if row["labels"]["site"] == "t_site"}
        assert by_shape == {"f32[4]": 1.0, "f32[8]": 1.0}
        assert len(calls) == 2           # python body ran once per variant


def test_count_trace_is_noop_when_disabled():
    obs.count_trace("t_disabled", jnp.zeros(3))
    assert obs.REGISTRY.get(obs.TRACE_COUNTER_NAME) is None or not [
        r for r in obs.REGISTRY.get(
            obs.TRACE_COUNTER_NAME)._values_list()
        if r["labels"]["site"] == "t_disabled"]


# ---------------------------------------------------------------------------
# 4. instrumented stack
# ---------------------------------------------------------------------------

def test_plan_cache_eviction_ticks_counter_without_changing_results(rng=None):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(3, 8, 2)).astype(np.float32))
    # two distinct word sets, alternated: under maxsize=1 each revisit evicts
    sets = [((0,),), ((0,), (1,)), ((0,),), ((0,), (1,))]
    try:
        ref = [np.asarray(ops.projected(x, w, backend="jax")) for w in sets]
        ops.set_plan_cache_maxsize(1)      # zeroes counters, bound=1
        got = [np.asarray(ops.projected(x, w, backend="jax")) for w in sets]
        info = ops.plan_cache_info()["_plan_for_words"]
        assert info.evictions >= 1, info   # alternating keys under maxsize=1
        assert info.misses >= 3, info
        assert info.maxsize == 1 and info.currsize <= 1
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a, b)
    finally:
        ops.set_plan_cache_maxsize(256)


def test_bounded_cache_eviction_counter():
    cache = ops.BoundedCache("t_obs_cache")
    try:
        ops.set_plan_cache_maxsize(2)
        for k in range(4):
            cache.get(k, lambda: k)
        info = cache.info()
        assert info.evictions == 2 and info.currsize == 2, info
        assert ops.plan_cache_info()["t_obs_cache"].evictions == 2
    finally:
        ops.set_plan_cache_maxsize(256)


def test_plan_cache_collector_publishes_gauges():
    with obs.enabled_scope():
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(2, 6, 2)).astype(np.float32))
        ops.projected(x, ((0,), (0, 1)), backend="jax")
        snap = obs.snapshot()
        rows = snap["metrics"]["pathsig_plan_cache"]["values"]
        stats = {(r["labels"]["cache"], r["labels"]["stat"]): r["value"]
                 for r in rows}
        assert any(k[1] == "misses" and v > 0 for k, v in stats.items())
        assert ("_pallas_sig_inverse", "evictions") in stats


def test_dispatch_call_counter_and_autotune_outcomes():
    with obs.enabled_scope():
        x = jnp.asarray(np.random.default_rng(1).normal(
            size=(2, 6, 2)).astype(np.float32))
        ops.signature(x, 2, backend="pallas_interpret")
        ops.signature(x, 2, backend="pallas_interpret")
        calls = obs.REGISTRY.get("pathsig_dispatch_calls_total")
        assert calls.value(op="signature", backend="pallas_interpret",
                           ctx="eager") == 2.0
        lookups = obs.REGISTRY.get("pathsig_autotune_lookups_total")
        assert lookups is not None and lookups.total() >= 2


def test_kernel_retrace_counter_under_repeat_calls():
    with obs.enabled_scope():
        x = jnp.asarray(np.random.default_rng(2).normal(
            size=(2, 5, 2)).astype(np.float32))
        for _ in range(3):
            ops.signature(x, 2, backend="pallas_interpret")
        c = obs.REGISTRY.get(obs.TRACE_COUNTER_NAME)
        sig_rows = [r for r in c._values_list()
                    if r["labels"]["site"] == "sig_trunc"
                    and "f32[2,5,2]" in r["labels"]["shapes"]]
        # three identical calls -> at most one fresh compile of this cell
        assert sum(r["value"] for r in sig_rows) <= 1.0, sig_rows


def test_dispatch_disabled_is_bitwise_transparent():
    x = jnp.asarray(np.random.default_rng(4).normal(
        size=(2, 7, 3)).astype(np.float32))
    a = np.asarray(ops.signature(x, 3, backend="jax"))
    with obs.enabled_scope():
        obs.start_trace(None)
        b = np.asarray(ops.signature(x, 3, backend="jax"))
        obs.TRACER._active = False
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# 5. baseline store + regression gate
# ---------------------------------------------------------------------------

def test_record_unit_floor_and_roundtrip():
    r = baseline.Record("s", "k/ms", 12.0, "ms")
    assert r.noise_floor == baseline.UNIT_NOISE_FLOORS["ms"]
    assert baseline.Record("s", "k/n", 3, "count").noise_floor == 0.0
    assert baseline.Record("s", "k/?", 1.0, "weird").noise_floor == 0.10
    # explicit floor survives json round-trip
    r2 = baseline.Record("s", "k", 5.0, "ms", True, 0.4)
    back = baseline.Record.from_json("s", r2.to_json())
    assert back == r2


def test_extract_records_native_schema_wins():
    doc = {"baseline_records": [
        {"key": "a/ms", "value": 3.0, "unit": "ms"},
        {"key": "a/thr", "value": 9.0, "unit": "req/s",
         "higher_is_better": True}],
        "records": [{"B": 1}]}      # would crash the per-shape extractor
    recs = baseline.extract_records("fig3", doc)
    assert [r.key for r in recs] == ["a/ms", "a/thr"]
    assert recs[1].higher_is_better


def test_extract_records_per_shape_sessions():
    doc = {"points": [{
        "n_sessions": 512,
        "pooled": {"updates_per_s_warm": 1000.0, "p99_staleness_s": 0.01,
                   "compiled_shapes": 3},
        "pooled_vs_per_object_speedup_warm": 40.0,
        "max_abs_err_pooled_vs_per_object": 1e-6}]}
    recs = {r.key: r for r in baseline.extract_records("sessions", doc)}
    assert recs["sessions/S512/pooled_updates_per_s_warm"].higher_is_better
    assert recs["sessions/S512/pooled_compiled_shapes"].noise_floor == 0.0
    assert recs["sessions/S512/pooled_p99_staleness_s"].value == 0.01
    # non-finite / missing values never become records
    doc["points"][0]["pooled"]["updates_per_s_warm"] = float("nan")
    del doc["points"][0]["pooled"]["p99_staleness_s"]
    keys = {r.key for r in baseline.extract_records("sessions", doc)}
    assert "sessions/S512/pooled_updates_per_s_warm" not in keys
    assert "sessions/S512/pooled_p99_staleness_s" not in keys


def test_aggregate_median_and_mad_widened_floor():
    runs = [[baseline.Record("s", "k/q", v, "q")] for v in
            (10.0, 100.0, 11.0)]    # one wild outlier (unknown-unit floor)
    (agg,) = baseline.aggregate(runs)
    assert agg.value == 11.0                    # median, not mean
    # MAD = 1.0 -> scaled rel floor 4.45/11 ~ 0.40 > unit floor 0.10
    assert agg.noise_floor == pytest.approx(3.0 * 1.4826 * 1.0 / 11.0)
    # quiet reruns keep the unit floor
    (q,) = baseline.aggregate([[baseline.Record("s", "k/q", 10.0, "q")],
                               [baseline.Record("s", "k/q", 10.1, "q")]])
    assert q.noise_floor == 0.10


def test_compare_verdict_directions():
    # explicit 25% floors so the assertions test direction logic, not the
    # machine-calibrated unit defaults
    base = {"s": [baseline.Record("s", "lat_ms", 10.0, "ms", False, 0.25),
                  baseline.Record("s", "thr", 100.0, "req/s", True, 0.25),
                  baseline.Record("s", "shapes", 4.0, "count"),
                  baseline.Record("s", "gone", 1.0, "ms", False, 0.25)]}
    cur = {"s": [baseline.Record("s", "lat_ms", 20.0, "ms", False, 0.25),
                 baseline.Record("s", "thr", 30.0, "req/s", True, 0.25),
                 baseline.Record("s", "shapes", 5.0, "count"),   # exact unit
                 baseline.Record("s", "fresh", 7.0, "ms", False, 0.25)]}
    v = {x.key: x for x in baseline.compare(cur, base)}
    assert v["lat_ms"].status == "regressed" and v["lat_ms"].rel_delta < 0
    assert v["thr"].status == "regressed"       # higher_is_better direction
    assert v["shapes"].status == "regressed"    # count floor is exact
    assert v["fresh"].status == "new"
    assert v["gone"].status == "missing"
    # improvements and in-floor jitter
    cur2 = {"s": [baseline.Record("s", "lat_ms", 5.0, "ms", False, 0.25),
                  baseline.Record("s", "thr", 101.0, "req/s", True, 0.25)]}
    v2 = {x.key: x for x in baseline.compare(cur2, base)}
    assert v2["lat_ms"].status == "improved"
    assert v2["thr"].status == "ok"             # +1% is inside the floor
    assert not baseline.regressions(baseline.compare(
        {"s": base["s"]}, base))                # self-compare is all ok


def test_verdict_table_orders_regressions_first():
    base = {"s": [baseline.Record("s", "a_ms", 10.0, "ms"),
                  baseline.Record("s", "b_ms", 10.0, "ms")]}
    cur = {"s": [baseline.Record("s", "a_ms", 10.0, "ms"),
                 baseline.Record("s", "b_ms", 99.0, "ms")]}
    txt = baseline.verdict_table(baseline.compare(cur, base))
    body = txt.splitlines()[2]                  # first data row
    assert body.startswith("regressed") and "b_ms" in body
    assert "2 metrics" in txt.splitlines()[-1]
    hidden = baseline.verdict_table(baseline.compare(cur, base),
                                    hide_ok=True)
    assert "a_ms" not in hidden and "b_ms" in hidden


def test_baseline_dir_roundtrip_and_schema_guard(tmp_path):
    recs = [baseline.Record("mysuite", "k/ms", 3.25, "ms", False, 0.3)]
    p = baseline.write_baseline(str(tmp_path), "mysuite", recs, reruns=3)
    assert json.load(open(p))["reruns"] == 3
    loaded = baseline.load_baseline_dir(str(tmp_path))
    assert loaded["mysuite"] == recs
    # future schema refuses to load silently-wrong
    doc = json.load(open(p))
    doc["schema"] = 99
    json.dump(doc, open(p, "w"))
    with pytest.raises(ValueError, match="schema"):
        baseline.load_baseline(p)
    assert baseline.load_baseline_dir(str(tmp_path / "nope")) == {}


def _run_gate(tmp_path, extra_args, env_overrides):
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [os.path.join(_REPO, "src"), _REPO]), **env_overrides)
    env.pop("PATHSIG_FIXTURE_RAISE", None)
    env.update(env_overrides)
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "fixture",
         "--baseline-dir", str(tmp_path / "baselines")] + extra_args,
        capture_output=True, text=True, env=env, cwd=str(tmp_path),
        timeout=300)


_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_regression_gate_cli_end_to_end(tmp_path):
    """update baselines -> clean compare exits 0 -> injected regression
    exits 2 (EXIT_REGRESSED) -> crash exits 1 (EXIT_CRASH)."""
    r = _run_gate(tmp_path, ["--update-baselines", "--reruns", "2"], {})
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert (tmp_path / "baselines" / "fixture.json").exists()

    r = _run_gate(tmp_path, ["--compare"], {})
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "regression gate" in r.stdout

    out = tmp_path / "verdicts.json"
    r = _run_gate(tmp_path, ["--compare", "--verdicts-out", str(out)],
                  {"PATHSIG_FIXTURE_MS": "20.0"})       # 2x latency
    assert r.returncode == 2, (r.returncode, r.stdout[-2000:])
    assert "regressed" in r.stdout
    doc = json.load(open(out))
    assert any(v["status"] == "regressed" and v["key"] == "fixture/latency_ms"
               for v in doc["verdicts"])

    r = _run_gate(tmp_path, ["--compare"], {"PATHSIG_FIXTURE_RAISE": "1"})
    assert r.returncode == 1, (r.returncode, r.stdout[-2000:])
    assert "CRASHED" in r.stdout and "fixture FAIL" in r.stdout


# ---------------------------------------------------------------------------
# 6. SLOs
# ---------------------------------------------------------------------------

def test_slo_evaluate_values_surfaces():
    slos = (obs.Slo("lat", "p99_s", 0.5),
            obs.Slo("thr", "rate", 10.0, op=">="),
            obs.Slo("ghost", "absent", 1.0))
    res = obs.evaluate_values(slos, {"p99_s": 0.7, "rate": 50.0})
    by = {r.slo.name: r for r in res}
    assert by["lat"].breached and by["lat"].observed == 0.7
    assert by["thr"].status == "ok"
    assert by["ghost"].status == "no_data" and not by["ghost"].breached
    # non-finite observations always breach
    (nan_r,) = obs.evaluate_values((obs.Slo("l", "v", 1.0),),
                                   {"v": float("nan")})
    assert nan_r.breached and nan_r.detail == "non-finite"
    rep = obs.slo.report(res)
    assert rep["status"] == "breach" and rep["breaches"] == ["lat"]
    assert json.loads(json.dumps(rep))["results"][0]["name"] == "lat"


def test_slo_bad_spec_raises():
    with pytest.raises(ValueError, match="op"):
        obs.Slo("x", "m", 1.0, op="!=")
    with pytest.raises(ValueError, match="reducer"):
        obs.Slo("x", "m", 1.0, reducer="p75")


def test_slo_evaluate_snapshot_group_by_worst():
    with obs.enabled_scope():
        c = obs.counter("t_retrace_total", "x", ("site",))
        c.inc(2, site="quiet")
        c.inc(40, site="noisy")
        h = obs.histogram("t_lat_s", "x")
        for v in [0.01] * 90 + [2.0] * 10:      # 10% tail -> p99 in the tail
            h.observe(v)
        snap = obs.snapshot()
    slos = (obs.Slo("budget", "t_retrace_total", 32, reducer="sum",
                    group_by="site"),
            obs.Slo("p99", "t_lat_s", 0.5, reducer="p99"),
            obs.Slo("absent", "t_nope", 1.0))
    by = {r.slo.name: r for r in obs.evaluate_snapshot(slos, snap)}
    assert by["budget"].breached and by["budget"].detail == "site=noisy"
    assert by["budget"].observed == 40.0        # the worst group, not the sum
    assert by["p99"].breached                   # p99 caught the tail
    assert by["absent"].status == "no_data"


def test_slo_evaluate_log_burn_rate(tmp_path):
    rows = [{"sec": 0.01} for _ in range(90)] + \
           [{"sec": 5.0} for _ in range(10)]
    budget = obs.Slo("steps", "sec", 1.0, budget=0.05)   # 5% allowed
    (r,) = obs.evaluate_log((budget,), rows, window=100)
    assert r.breached and r.burn_rate == pytest.approx(2.0)  # 10% / 5%
    (ok,) = obs.evaluate_log((obs.Slo("steps", "sec", 1.0, budget=0.2),),
                             rows, window=100)
    assert ok.status == "ok" and ok.burn_rate == pytest.approx(0.5)
    # trailing window drops old violations
    (w,) = obs.evaluate_log((budget,), rows[:95], window=5)
    assert w.breached                      # window is all-violating tail
    path = tmp_path / "log.jsonl"
    path.write_text("\n".join(json.dumps(x) for x in rows) + "\nnot json\n")
    (f,) = obs.evaluate_log((budget,), str(path), window=100)
    assert f.breached and "violating" in f.detail


def test_session_store_health_flags_staleness_breach():
    from repro.serve.sessions import SessionStore
    store = SessionStore(d=2, depth=2, initial_sessions=4)
    h = store.health()
    assert h["status"] == "ok"
    # seed the staleness window with a breach of the 0.25 s default
    store._staleness.extend([1.0] * 100)
    h = store.health()
    assert h["status"] == "breach"
    assert "sessions_p99_staleness" in h["breaches"]
    # custom bundle overrides the default
    h2 = store.health(slos=(obs.Slo("lax", "p99_staleness_s", 10.0),))
    assert h2["status"] == "ok"


def test_batcher_health_and_flush_latency_stats():
    from repro.serve import DynamicBatcher
    db = DynamicBatcher.signature_service(2, 2, max_len=16, backend="jax",
                                          min_bucket=4, max_batch=8)
    rng = np.random.default_rng(0)
    for L in (3, 7, 5):
        db.submit(np.cumsum(rng.normal(size=(L + 1, 2)).astype(np.float32),
                            axis=0))
    db.flush()
    st = db.stats()
    assert st["flushes_recorded"] == 1 and st["flush_p99_s"] > 0
    assert db.health()["status"] == "ok"
    h = db.health(slos=(obs.Slo("tight", "flush_p99_s", 1e-12),))
    assert h["status"] == "breach" and h["breaches"] == ["tight"]


def _tiny_train_cfg():
    from repro.configs import get_config, reduce_config
    return dataclasses.replace(reduce_config(get_config("qwen3-4b")),
                               n_layers=1, d_model=32, n_heads=2,
                               n_kv_heads=2, head_dim=16, d_ff=64,
                               vocab_size=64)


def _tiny_train(loop, steps_seen=None):
    import repro.models as M
    from repro.data.pipeline import TokenStream
    from repro.optim import adamw
    from repro.train import train_loop
    cfg = _tiny_train_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    it = iter(TokenStream(64, 2, 8, seed=0))
    if steps_seen is not None:
        base = it

        def counting():
            for b in base:
                steps_seen.append(1)
                yield b
        it = counting()
    return train_loop(cfg, params, adamw(lr=1e-3), it, loop)


@pytest.mark.slow
def test_train_loop_slo_warn_and_abort(tmp_path, monkeypatch):
    from repro.train import TrainLoopConfig
    monkeypatch.setenv("PATHSIG_FLIGHT_DIR", str(tmp_path))
    # impossible p99 budget in warn mode: completes, but warns
    loop = TrainLoopConfig(steps=3, log_every=1, run_dir="",
                           slos=obs.train_slos(step_p99_s=1e-9))
    with pytest.warns(UserWarning, match="SLO breach"):
        _, _, hist = _tiny_train(loop)
    assert len(hist) == 3                       # run was not aborted
    # abort mode raises SloBreach and leaves a flight dump behind
    calls = []
    loop = TrainLoopConfig(steps=3, log_every=1, run_dir="",
                           slos=obs.train_slos(step_p99_s=1e-9),
                           slo_action="abort",
                           slo_callback=lambda s, rep: calls.append(rep))
    with pytest.raises(obs.SloBreach, match="train_step_p99"):
        _tiny_train(loop)
    assert calls and calls[0]["status"] == "breach"
    dumps = list(tmp_path.glob("flight_*.json"))
    assert len(dumps) == 1
    doc = json.load(open(dumps[0]))
    assert doc["otherData"]["exception"]["type"] == "SloBreach"
    # healthy budgets: silent, full history
    loop = TrainLoopConfig(steps=3, log_every=1, run_dir="",
                           slos=obs.train_slos())
    _, _, hist = _tiny_train(loop)
    assert len(hist) == 3


# ---------------------------------------------------------------------------
# 7. flight recorder + bounded-ring satellites
# ---------------------------------------------------------------------------

def test_flight_ring_is_bounded():
    from repro.obs.flight import FlightRecorder
    fl = FlightRecorder(capacity=8, retrace_keys=2)
    for i in range(20):
        fl.record_span(f"s{i}", 0.0, 1.0, 0, None)
        fl.record_retrace("site", f"k{i}")
    assert len(fl) == 8
    doc = fl.to_chrome()
    assert [e["name"] for e in doc["traceEvents"]] == \
        [f"s{i}" for i in range(12, 20)]        # most-recent survive
    assert [r["shapes"] for r in doc["otherData"]["retrace_keys"]] == \
        ["k18", "k19"]


def test_flight_dump_contents_and_metric_series(tmp_path):
    from repro.obs.flight import FlightRecorder
    fl = FlightRecorder(capacity=32)
    fl.record_span("serve.flush", 1.0, 1.5, 0, {"rungs": 2})
    fl.record_instant("evict", {"sid": "a"})
    fl.record_metric("counter", "t_total", {"op": "x"}, 3.0)
    fl.record_retrace("sig_trunc", "f32[2,5,2]")
    try:
        raise ValueError("boom")
    except ValueError as e:
        p = fl.dump(str(tmp_path / "f.json"), exc=e, note="unit")
    doc = json.load(open(p))
    evs = {e["ph"]: e for e in doc["traceEvents"]}
    assert evs["X"]["name"] == "serve.flush" and evs["X"]["dur"] == 5e5
    assert evs["X"]["args"]["rungs"] == 2
    assert evs["i"]["args"] == {"sid": "a"}
    assert evs["C"]["name"] == "t_total{op=x}"      # labelled series
    other = doc["otherData"]
    assert other["note"] == "unit"
    assert other["retrace_keys"][0]["site"] == "sig_trunc"
    assert other["exception"]["type"] == "ValueError"
    assert "boom" in other["exception"]["traceback"]
    assert fl.dumps == 1


def test_spans_feed_flight_even_without_active_trace(tmp_path):
    """The always-on path: no start_trace, yet obs.span lands in the
    flight ring (this is what makes post-mortem dumps non-empty)."""
    from repro.obs.flight import FlightRecorder, disable_flight, \
        enable_flight
    fl = FlightRecorder(capacity=16)
    enable_flight(fl)
    try:
        assert not obs.trace_active()
        with obs.span("quiet.work", k=1):
            pass
        obs.instant("quiet.mark")
        # metric deltas mirror only when the registry is enabled
        with obs.enabled_scope():
            obs.counter("t_flight_total", "x").inc(2)
        names = [e[1] for e in fl._ring]
        assert "quiet.work" in names and "quiet.mark" in names
        assert "t_flight_total" in names
        # ...and the trace-file buffer stayed empty
        assert obs.TRACER.events == []
    finally:
        disable_flight()
        obs.enable_flight()                     # restore module default


def test_dump_on_error_dumps_once_across_nested_boundaries(tmp_path,
                                                           monkeypatch):
    monkeypatch.setenv("PATHSIG_FLIGHT_DIR", str(tmp_path))
    with pytest.raises(RuntimeError, match="inner"):
        with obs.dump_on_error("outer.site"):
            with obs.dump_on_error("inner.site"):
                raise RuntimeError("inner boom")
    dumps = list(tmp_path.glob("flight_*.json"))
    assert len(dumps) == 1                      # marker stopped the second
    doc = json.load(open(dumps[0]))
    assert doc["otherData"]["note"] == "inner.site"
    assert doc["otherData"]["exception"]["message"] == "inner boom"


@pytest.mark.slow
def test_train_loop_crash_leaves_flight_dump(tmp_path, monkeypatch):
    """Acceptance: an induced train_loop exception produces a flight dump
    holding the last-N spans and the exception."""
    from repro.train import TrainLoopConfig
    monkeypatch.setenv("PATHSIG_FLIGHT_DIR", str(tmp_path))
    obs.FLIGHT.clear()

    def dying_iter():
        from repro.data.pipeline import TokenStream
        it = iter(TokenStream(64, 2, 8, seed=0))
        yield next(it)
        yield next(it)
        raise RuntimeError("data pipeline died")

    import repro.models as M
    from repro.optim import adamw
    from repro.train import train_loop
    cfg = _tiny_train_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    loop = TrainLoopConfig(steps=5, log_every=1, run_dir="")
    with pytest.raises(RuntimeError, match="data pipeline died"):
        train_loop(cfg, params, adamw(lr=1e-3), dying_iter(), loop)
    dumps = list(tmp_path.glob("flight_*.json"))
    assert len(dumps) == 1
    doc = json.load(open(dumps[0]))
    other = doc["otherData"]
    assert other["exception"]["type"] == "RuntimeError"
    assert "data pipeline died" in other["exception"]["message"]
    spans = [e for e in doc["traceEvents"]
             if e["ph"] == "X" and e["name"] == "train.step"]
    assert len(spans) >= 2                      # the completed steps


def test_sigusr2_dumps_live_ring(tmp_path, monkeypatch):
    import signal
    from repro.obs import flight
    if not flight._SIG_INSTALLED:
        pytest.skip("SIGUSR2 hook not installed in this process")
    monkeypatch.setenv("PATHSIG_FLIGHT_DIR", str(tmp_path))
    with obs.span("pre.signal"):
        pass
    os.kill(os.getpid(), signal.SIGUSR2)
    dumps = list(tmp_path.glob("flight_*.json"))
    assert len(dumps) == 1
    doc = json.load(open(dumps[0]))
    assert doc["otherData"]["note"] == "SIGUSR2"
    assert any(e["name"] == "pre.signal" for e in doc["traceEvents"])


def test_trace_ring_bounds_events_and_counts_drops():
    from repro.obs.trace import DROP_COUNTER_NAME, Tracer
    t = Tracer(max_events=3)
    t.start()
    with obs.enabled_scope():
        for i in range(8):
            with t.span(f"s{i}"):
                pass
        assert len(t.events) == 3
        assert [e["name"] for e in t.events] == ["s5", "s6", "s7"]
        assert t.dropped == 5
        assert obs.counter(DROP_COUNTER_NAME, "x").value() == 5.0
    t.stop()
    t.clear()
    assert t.dropped == 0
    # env sizing is honoured (and clamped to >= 1)
    os.environ["PATHSIG_TRACE_MAX_EVENTS"] = "2"
    try:
        assert Tracer()._max_events == 2
    finally:
        del os.environ["PATHSIG_TRACE_MAX_EVENTS"]


def test_metric_label_cardinality_guard():
    from repro.obs.metrics import CARDINALITY_DROP_COUNTER, Registry
    reg = Registry(enabled=True, max_label_sets=3)
    c = reg.counter("t_wild_total", "x", ("rid",))
    with pytest.warns(UserWarning, match="cardinality"):
        for i in range(10):
            c.inc(rid=f"r{i}")
    assert len(c._values) == 3                  # capped
    assert c.value(rid="r0") == 1.0 and c.value(rid="r9") == 0.0
    drops = reg.counter(CARDINALITY_DROP_COUNTER, "x", ("metric",))
    assert drops.value(metric="t_wild_total") == 7.0
    # existing label sets keep updating after the cap
    c.inc(rid="r1")
    assert c.value(rid="r1") == 2.0
    # histograms share the guard
    h = reg.histogram("t_wild_seconds", "x", ("rid",))
    with pytest.warns(UserWarning, match="cardinality"):
        for i in range(6):
            h.observe(0.1, rid=f"r{i}")
    assert h.count(rid="r5") == 0 and h.count(rid="r0") == 1
    # reset clears values and re-arms the warn-once
    reg.reset()
    assert not c._values and not c._card_warned
