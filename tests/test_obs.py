"""repro.obs: metrics registry, span tracer, compile accounting, and the
instrumented stack.

Contracts under test:

1. *Registry semantics*: counters/gauges/histograms with label sets;
   disabled instruments are no-ops; histograms never return NaN
   percentiles; snapshots / Prometheus text / JSONL export round-trip.
2. *Tracer*: spans nest correctly (depth metadata + containment), the
   Chrome-trace file round-trips through ``json.load`` with the expected
   event schema, and disabled mode adds no measurable overhead
   (guard-banded timing).
3. *Compile accounting*: ``instrument_jit`` ticks exactly one counter per
   compiled variant, labelled with the offending shape key; cached calls
   add nothing.
4. *Instrumented stack*: plan-cache eviction ticks the new ``evictions``
   counter without changing results; autotune lookups record outcomes;
   dispatch entries count calls.
"""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.kernels import ops


@pytest.fixture(autouse=True)
def clean_registry():
    """Each test starts disabled with zeroed instruments and no active
    trace (module import may have inherited env state)."""
    obs.disable()
    obs.reset()
    if obs.trace_active():
        obs.TRACER._active = False
    obs.TRACER.clear()
    yield
    obs.disable()
    obs.reset()
    obs.TRACER._active = False
    obs.TRACER.clear()


# ---------------------------------------------------------------------------
# 1. registry
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_label_sets():
    with obs.enabled_scope():
        c = obs.counter("t_requests_total", "x", ("op",))
        c.inc(op="a")
        c.inc(2, op="a")
        c.inc(op="b")
        assert c.value(op="a") == 3.0
        assert c.value(op="b") == 1.0
        assert c.total() == 4.0

        g = obs.gauge("t_depth", "x", ("pool",))
        g.set(7, pool="p")
        g.add(-2, pool="p")
        assert g.value(pool="p") == 5.0

        h = obs.histogram("t_lat_seconds", "x", ("site",))
        for v in (1e-4, 2e-4, 5e-2):
            h.observe(v, site="s")
        assert h.count(site="s") == 3
        assert 0 < h.percentile(50, site="s") < 5e-2


def test_disabled_instruments_are_noops():
    c = obs.counter("t_off_total", "x", ("op",))
    h = obs.histogram("t_off_seconds", "x")
    g = obs.gauge("t_off_gauge", "x")
    c.inc(op="a")
    h.observe(1.0)
    g.set(3.0)
    assert c.total() == 0.0
    assert h.count() == 0
    assert g.value() == 0.0


def test_histogram_empty_percentile_is_zero_not_nan():
    with obs.enabled_scope():
        h = obs.histogram("t_empty_seconds", "x", ("site",))
        p = h.percentile(50, site="never_observed")
        assert p == 0.0 and not np.isnan(p)


def test_metric_type_conflict_raises():
    with obs.enabled_scope():
        obs.counter("t_conflict", "x", ("a",))
        with pytest.raises(ValueError, match="already registered"):
            obs.gauge("t_conflict", "x", ("a",))
        with pytest.raises(ValueError, match="already registered"):
            obs.counter("t_conflict", "x", ("b",))


def test_missing_label_raises():
    with obs.enabled_scope():
        c = obs.counter("t_labels_total", "x", ("op", "backend"))
        with pytest.raises(ValueError, match="missing"):
            c.inc(op="a")


def test_snapshot_prometheus_and_jsonl_roundtrip(tmp_path):
    with obs.enabled_scope():
        obs.counter("t_snap_total", "help text", ("op",)).inc(3, op="sig")
        obs.histogram("t_snap_seconds", "h", ()).observe(0.01)
        snap = obs.snapshot()
        assert snap["metrics"]["t_snap_total"]["type"] == "counter"
        assert snap["metrics"]["t_snap_total"]["values"][0] == {
            "labels": {"op": "sig"}, "value": 3.0}

        p = obs.write_snapshot(str(tmp_path / "snap.json"))
        assert json.load(open(p))["metrics"]["t_snap_total"]["values"]

        jl = str(tmp_path / "snap.jsonl")
        obs.append_jsonl(jl, extra={"suite": "x"})
        obs.append_jsonl(jl)
        lines = [json.loads(ln) for ln in open(jl)]
        assert len(lines) == 2 and lines[0]["suite"] == "x"

        text = obs.to_prometheus()
        assert "# TYPE t_snap_total counter" in text
        assert 't_snap_total{op="sig"} 3.0' in text
        assert "t_snap_seconds_bucket" in text
        assert "t_snap_seconds_count 1" in text


def test_collector_runs_at_snapshot_time():
    calls = []
    reg = obs.Registry(enabled=True)
    reg.register_collector(lambda r: calls.append(1) or r.gauge(
        "t_pulled", "x").set(42.0))
    snap = reg.snapshot()
    assert calls == [1]
    assert snap["metrics"]["t_pulled"]["values"][0]["value"] == 42.0


def test_jsonl_sink_appends_per_call(tmp_path):
    sink = obs.jsonl_sink(str(tmp_path / "run.jsonl"))
    sink(0, {"loss": 1.5})
    sink(10, {"loss": 0.5, "straggler": True})
    lines = [json.loads(ln) for ln in open(sink.path)]
    assert [ln["step"] for ln in lines] == [0, 10]
    assert lines[1]["straggler"] is True


# ---------------------------------------------------------------------------
# 2. tracer
# ---------------------------------------------------------------------------

def test_spans_nest_and_chrome_trace_roundtrips(tmp_path):
    path = str(tmp_path / "trace.json")
    with obs.trace_scope(path):
        with obs.span("outer", layer="serve"):
            time.sleep(0.002)
            with obs.span("inner"):
                time.sleep(0.001)
        obs.instant("marker", n=1)
    doc = json.load(open(path))
    assert set(doc) >= {"traceEvents", "displayTimeUnit"}
    evs = {e["name"]: e for e in doc["traceEvents"]}
    assert {"outer", "inner", "marker"} <= set(evs)
    # schema: complete events carry ph/ts/dur/pid/tid/args
    for name in ("outer", "inner"):
        e = evs[name]
        assert e["ph"] == "X"
        assert {"ts", "dur", "pid", "tid", "args"} <= set(e)
    assert evs["marker"]["ph"] == "i"
    # nesting: depth metadata + interval containment on one track
    assert evs["outer"]["args"]["depth"] == 0
    assert evs["inner"]["args"]["depth"] == 1
    assert evs["outer"]["ts"] <= evs["inner"]["ts"]
    assert (evs["inner"]["ts"] + evs["inner"]["dur"]
            <= evs["outer"]["ts"] + evs["outer"]["dur"] + 1.0)
    assert evs["outer"]["args"]["layer"] == "serve"


def test_spans_nest_under_jit_boundaries(tmp_path):
    """Spans opened around and inside (at trace time) a jit call keep
    consistent nesting — the inner span is emitted at trace time only."""
    path = str(tmp_path / "trace.json")

    def f(x):
        with obs.span("jit.body"):
            return x * 2

    jf = jax.jit(f)
    x = jnp.ones(4)
    with obs.trace_scope(path):
        with obs.span("call.outer"):
            jf(x).block_until_ready()      # compiles: body span emitted
        with obs.span("call.cached"):
            jf(x).block_until_ready()      # cached: no new body span
    evs = json.load(open(path))["traceEvents"]
    body = [e for e in evs if e["name"] == "jit.body"]
    outer = [e for e in evs if e["name"] == "call.outer"]
    assert len(body) == 1 and len(outer) == 1
    assert body[0]["args"]["depth"] == outer[0]["args"]["depth"] + 1


def test_disabled_tracing_adds_no_measurable_overhead():
    """Guard-banded absolute bound: a disabled span costs well under 10us
    per entry (typical ~0.5us — one flag check + the null-span singleton).
    The generous band absorbs CI scheduling noise."""
    N = 20_000

    def instrumented():
        t0 = time.perf_counter()
        acc = 0
        for i in range(N):
            with obs.span("hot"):
                acc += i
        return time.perf_counter() - t0

    assert not obs.trace_active() and not obs.enabled()
    instrumented()   # warm
    per_span = min(instrumented() for _ in range(5)) / N
    assert per_span < 10e-6, f"{per_span * 1e6:.2f}us per disabled span"


def test_null_span_supports_set():
    s = obs.span("inactive", a=1)
    assert s.set(b=2) is s
    with s:
        pass


# ---------------------------------------------------------------------------
# 3. compile accounting
# ---------------------------------------------------------------------------

def test_shape_key_describes_arrays_and_pytrees():
    x = jnp.zeros((4, 10, 3), jnp.float32)
    key = obs.shape_key(x, depth=3, split=None)
    assert "f32[4,10,3]" in key and "depth=3" in key

    key2 = obs.shape_key({"a": x, "b": [x, x]})
    assert "a:f32[4,10,3]" in key2


def test_instrument_jit_counts_one_trace_per_variant():
    with obs.enabled_scope():
        calls = []

        def f(x):
            calls.append(1)
            return x + 1

        jf = obs.instrument_jit(f, site="t_site")
        x4 = jnp.zeros(4)
        x8 = jnp.zeros(8)
        jf(x4); jf(x4); jf(x4)          # one compile, three calls
        jf(x8)                           # second variant
        c = obs.REGISTRY.get(obs.TRACE_COUNTER_NAME)
        by_shape = {row["labels"]["shapes"]: row["value"]
                    for row in c._values_list()
                    if row["labels"]["site"] == "t_site"}
        assert by_shape == {"f32[4]": 1.0, "f32[8]": 1.0}
        assert len(calls) == 2           # python body ran once per variant


def test_count_trace_is_noop_when_disabled():
    obs.count_trace("t_disabled", jnp.zeros(3))
    assert obs.REGISTRY.get(obs.TRACE_COUNTER_NAME) is None or not [
        r for r in obs.REGISTRY.get(
            obs.TRACE_COUNTER_NAME)._values_list()
        if r["labels"]["site"] == "t_disabled"]


# ---------------------------------------------------------------------------
# 4. instrumented stack
# ---------------------------------------------------------------------------

def test_plan_cache_eviction_ticks_counter_without_changing_results(rng=None):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(3, 8, 2)).astype(np.float32))
    # two distinct word sets, alternated: under maxsize=1 each revisit evicts
    sets = [((0,),), ((0,), (1,)), ((0,),), ((0,), (1,))]
    try:
        ref = [np.asarray(ops.projected(x, w, backend="jax")) for w in sets]
        ops.set_plan_cache_maxsize(1)      # zeroes counters, bound=1
        got = [np.asarray(ops.projected(x, w, backend="jax")) for w in sets]
        info = ops.plan_cache_info()["_plan_for_words"]
        assert info.evictions >= 1, info   # alternating keys under maxsize=1
        assert info.misses >= 3, info
        assert info.maxsize == 1 and info.currsize <= 1
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a, b)
    finally:
        ops.set_plan_cache_maxsize(256)


def test_bounded_cache_eviction_counter():
    cache = ops.BoundedCache("t_obs_cache")
    try:
        ops.set_plan_cache_maxsize(2)
        for k in range(4):
            cache.get(k, lambda: k)
        info = cache.info()
        assert info.evictions == 2 and info.currsize == 2, info
        assert ops.plan_cache_info()["t_obs_cache"].evictions == 2
    finally:
        ops.set_plan_cache_maxsize(256)


def test_plan_cache_collector_publishes_gauges():
    with obs.enabled_scope():
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(2, 6, 2)).astype(np.float32))
        ops.projected(x, ((0,), (0, 1)), backend="jax")
        snap = obs.snapshot()
        rows = snap["metrics"]["pathsig_plan_cache"]["values"]
        stats = {(r["labels"]["cache"], r["labels"]["stat"]): r["value"]
                 for r in rows}
        assert any(k[1] == "misses" and v > 0 for k, v in stats.items())
        assert ("_pallas_sig_inverse", "evictions") in stats


def test_dispatch_call_counter_and_autotune_outcomes():
    with obs.enabled_scope():
        x = jnp.asarray(np.random.default_rng(1).normal(
            size=(2, 6, 2)).astype(np.float32))
        ops.signature(x, 2, backend="pallas_interpret")
        ops.signature(x, 2, backend="pallas_interpret")
        calls = obs.REGISTRY.get("pathsig_dispatch_calls_total")
        assert calls.value(op="signature", backend="pallas_interpret",
                           ctx="eager") == 2.0
        lookups = obs.REGISTRY.get("pathsig_autotune_lookups_total")
        assert lookups is not None and lookups.total() >= 2


def test_kernel_retrace_counter_under_repeat_calls():
    with obs.enabled_scope():
        x = jnp.asarray(np.random.default_rng(2).normal(
            size=(2, 5, 2)).astype(np.float32))
        for _ in range(3):
            ops.signature(x, 2, backend="pallas_interpret")
        c = obs.REGISTRY.get(obs.TRACE_COUNTER_NAME)
        sig_rows = [r for r in c._values_list()
                    if r["labels"]["site"] == "sig_trunc"
                    and "f32[2,5,2]" in r["labels"]["shapes"]]
        # three identical calls -> at most one fresh compile of this cell
        assert sum(r["value"] for r in sig_rows) <= 1.0, sig_rows


def test_dispatch_disabled_is_bitwise_transparent():
    x = jnp.asarray(np.random.default_rng(4).normal(
        size=(2, 7, 3)).astype(np.float32))
    a = np.asarray(ops.signature(x, 3, backend="jax"))
    with obs.enabled_scope():
        obs.start_trace(None)
        b = np.asarray(ops.signature(x, 3, backend="jax"))
        obs.TRACER._active = False
    np.testing.assert_array_equal(a, b)
