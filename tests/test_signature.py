"""Signature engine tests: Horner scan vs exp/Chen oracle, algebraic laws."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core as C
from repro.core import tensor_ops as tops
from tests.conftest import make_path


@pytest.mark.parametrize("d,N", [(2, 5), (3, 4), (5, 3), (8, 2), (1, 4)])
def test_horner_matches_oracle(rng, d, N):
    path = make_path(rng, 4, 17, d)
    incs = tops.path_increments(jnp.asarray(path))
    np.testing.assert_allclose(
        C.signature(path, N), tops.signature_exp_chen(incs, N),
        rtol=1e-5, atol=1e-5)


def test_level1_is_total_increment(rng):
    path = make_path(rng, 3, 9, 4)
    s = C.signature(path, 3)
    np.testing.assert_allclose(s[:, :4], path[:, -1] - path[:, 0],
                               rtol=1e-5, atol=1e-6)


def test_level2_shuffle_identity(rng):
    """sym(S^(2)) = S^(1) ⊗ S^(1) / 2 — the first shuffle relation."""
    d = 3
    path = make_path(rng, 5, 11, d)
    s = C.signature(path, 2)
    s1, s2 = s[:, :d], s[:, d:].reshape(-1, d, d)
    sym = 0.5 * (s2 + np.swapaxes(np.asarray(s2), 1, 2))
    np.testing.assert_allclose(
        sym, 0.5 * np.einsum("bi,bj->bij", np.asarray(s1), np.asarray(s1)),
        rtol=1e-4, atol=1e-5)


def test_chen_relation(rng):
    """S_{0,T} = S_{0,u} ⊗ S_{u,T} (Thm 3.2)."""
    path = make_path(rng, 2, 20, 3)
    full = C.signature(path, 4)
    left = C.signature(path[:, :11], 4)
    right = C.signature(path[:, 10:], 4)
    np.testing.assert_allclose(C.signature_combine(left, right, 3, 4), full,
                               rtol=1e-4, atol=1e-5)


def test_time_reversal_inverse(rng):
    """S(X)^{-1} = S(reversed X) (Lemma 4.5)."""
    path = make_path(rng, 2, 15, 3)
    fwd = C.signature(path, 3)
    bwd = C.signature(path[:, ::-1], 3)
    np.testing.assert_allclose(C.signature_inverse(fwd, 3, 3), bwd,
                               rtol=1e-4, atol=1e-5)


def test_reparametrisation_invariance(rng):
    """Signatures are invariant under time reparametrisation (§1)."""
    path = make_path(rng, 2, 10, 3)
    # insert a repeated sample (zero increment) — a reparametrisation
    path2 = np.concatenate([path[:, :5], path[:, 4:5], path[:, 5:]], axis=1)
    np.testing.assert_allclose(C.signature(path2, 4), C.signature(path, 4),
                               rtol=1e-5, atol=1e-6)


def test_single_linear_segment_is_tensor_exponential(rng):
    """Prop 3.1: one affine segment -> S = exp(ΔX)."""
    d, N = 4, 5
    dx = rng.normal(size=(1, d)).astype(np.float32) * 0.5
    path = np.stack([np.zeros((1, d), np.float32), dx[0][None]], axis=1)
    s = C.signature(path, N)
    e = tops.levels_to_flat(tops.tensor_exp(jnp.asarray(dx), N))
    np.testing.assert_allclose(s, e, rtol=1e-5, atol=1e-6)


def test_stream_mode_prefix_signatures(rng):
    path = make_path(rng, 2, 8, 2)
    stream = C.signature(path, 3, stream=True)
    for j in (1, 4, 8):
        np.testing.assert_allclose(stream[:, j - 1],
                                   C.signature(path[:, :j + 1], 3),
                                   rtol=1e-5, atol=1e-6)


@given(st.integers(2, 4), st.integers(1, 4), st.integers(2, 12))
@settings(max_examples=15, deadline=None)
def test_scaling_property(d, N, M):
    """S^(n)(λX) = λ^n S^(n)(X) — gradedness property check."""
    rng = np.random.default_rng(d * 100 + N * 10 + M)
    path = make_path(rng, 2, M, d)
    lam = 0.7
    s1 = np.asarray(C.signature(path, N))
    s2 = np.asarray(C.signature(lam * path, N))
    off = 0
    for n in range(1, N + 1):
        blk = slice(off, off + d ** n)
        np.testing.assert_allclose(s2[:, blk], lam ** n * s1[:, blk],
                                   rtol=1e-4, atol=1e-5)
        off += d ** n


def test_tensor_log_exp_roundtrip(rng):
    path = make_path(rng, 3, 12, 2)
    s = tops.flat_to_levels(jnp.asarray(C.signature(path, 4)), 2, 4)
    logs = tops.tensor_log(s)
    # exp(log(S)) = S : rebuild exp via series of the log element
    one = [jnp.zeros_like(l) for l in logs]
    term = [jnp.zeros_like(l) for l in logs]
    acc = one
    term_k = logs
    acc = [a + t for a, t in zip(acc, term_k)]
    fact = 1.0
    power = logs
    for k in range(2, 5):
        power = tops.chen_mul(power, logs, a0=0.0, b0=0.0,
                              min_level_a=k - 1, min_level_b=1)
        fact *= k
        acc = [a + p / fact for a, p in zip(acc, power)]
    for a, b in zip(acc, s):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
