"""Mesh-sharded signature stack: sharded vs unsharded equivalence.

The SPMD contract of ``repro.kernels.ops`` (see the mesh note in its
docstring) has three testable halves:

1. *No context -> bit-identical*: without ``sharding_ctx`` the mesh branch
   is never taken, so outputs and grads match the seed to the bit (also true
   under a context whose batch axis has one shard).
2. *Context -> same answers*: under an 8-host-device mesh every dispatch
   cell (backend × backward × stream × lengths), the Gram ring route, the
   sig-MMD trainer loss and the DynamicBatcher placement all agree with
   their unsharded oracles.
3. *The communication law*: the Gram ring moves O(B·D_sig) bytes over
   collective-permutes — no all-gather of the (B_x, B_y, D_sig) elementwise
   intermediate (asserted on lowered HLO via
   ``repro.distributed.hlo.collective_stats``).

Multi-device execution happens in subprocesses (the main test process must
keep seeing 1 device — XLA locks the count at first init), matching
``test_distributed.py``.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.distributed import collective_stats, sharding_ctx
    from repro.kernels import ops
    from repro.launch.mesh import make_sig_mesh

    assert len(jax.devices()) == 8, jax.devices()
    mesh = make_sig_mesh()
    B, M, d, depth = 6, 8, 2, 3      # B=6: exercises padding up to 8
    x = jax.random.normal(jax.random.PRNGKey(0), (B, M, d)) * 0.2
    lens = jnp.asarray([8, 3, 0, 5, 1, 7], jnp.int32)
    words = ((0,), (1, 0), (0, 1, 1))

    def check(f, tag, rtol=1e-6, atol=1e-6):
        ref = f(x)
        gref = jax.grad(lambda a: (f(a) ** 2).sum())(x)
        with sharding_ctx(mesh):
            got = f(x)
            ggot = jax.grad(lambda a: (f(a) ** 2).sum())(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=rtol, atol=atol, err_msg=tag)
        np.testing.assert_allclose(np.asarray(ggot), np.asarray(gref),
                                   rtol=10 * rtol, atol=atol, err_msg=tag)
        print("ok", tag, flush=True)
""")

_TRUNCATED = _PRELUDE + textwrap.dedent("""
    for backend in ("jax", "pallas_interpret"):
        for backward in ("inverse", "checkpoint", "autodiff"):
            for stream in (False, True):
                if stream and backward == "checkpoint":
                    continue
                for lengths in (None, lens):
                    def f(a, be=backend, bw=backward, st=stream, ln=lengths):
                        return ops.signature(a, depth, backend=be,
                                             backward=bw, stream=st,
                                             stream_stride=3, lengths=ln)
                    check(f, f"sig/{backend}/{backward}/{stream}/"
                             f"{lengths is not None}")
    print("SHARDOK truncated")
""")

_PROJECTED = _PRELUDE + textwrap.dedent("""
    for backend in ("jax", "pallas_interpret"):
        for backward in ("inverse", "checkpoint", "autodiff"):
            for stream in (False, True):
                if stream and backward == "checkpoint":
                    continue
                for lengths in (None, lens):
                    def f(a, be=backend, bw=backward, st=stream, ln=lengths):
                        return ops.projected(a, words, backend=be,
                                             backward=bw, stream=st,
                                             stream_stride=3, lengths=ln)
                    check(f, f"proj/{backend}/{backward}/{stream}/"
                             f"{lengths is not None}")
    for backward in ("inverse", "checkpoint", "autodiff"):
        def f(a, bw=backward):
            return ops.projected(a, words, backend="hybrid", backward=bw)
        check(f, f"proj/hybrid/{backward}")
    # inference-only path
    ref = ops.projected_forward_only(x, words, backend="pallas_interpret")
    with sharding_ctx(mesh):
        got = ops.projected_forward_only(x, words,
                                         backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    print("SHARDOK projected")
""")

_GRAM = _PRELUDE + textwrap.dedent("""
    from repro.sigkernel import sig_gram, sig_mmd

    Bx, By, D = 24, 20, 120          # d=3 depth=4 word space
    Sx = jax.random.normal(jax.random.PRNGKey(1), (Bx, D))
    Sy = jax.random.normal(jax.random.PRNGKey(2), (By, D))
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (D,))) + 0.1
    oracle = (Sx * w[None]) @ Sy.T
    for backend in ("jax", "pallas_interpret"):
        with sharding_ctx(mesh):
            got = ops.gram(Sx, Sy, w, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                                   rtol=1e-5, atol=1e-5, err_msg=backend)
    # grads of all three operands through the ring
    def loss(a, b, c):
        return (ops.gram(a, b, c, backend="jax") ** 2).sum()
    g0 = jax.grad(loss, argnums=(0, 1, 2))(Sx, Sy, w)
    with sharding_ctx(mesh):
        g1 = jax.grad(loss, argnums=(0, 1, 2))(Sx, Sy, w)
    for a, b in zip(g1, g0):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)

    # the communication law on lowered HLO: collective-permutes move the
    # Y tiles (O(B.D_sig) total); NO all-gather ever carries the
    # (B_x, B_y, D_sig) elementwise intermediate
    with sharding_ctx(mesh):
        txt = jax.jit(lambda a, b, c: ops.gram(a, b, c, backend="jax")
                      ).lower(Sx, Sy, w).compile().as_text()
    st = collective_stats(txt, default_group=8)
    print(st.summary(), flush=True)
    assert "collective-permute" in st.by_kind, st.by_kind
    blowup = Bx * By * D * 4
    ag = st.by_kind.get("all-gather", (0, 0.0, 0.0))
    assert ag[1] < blowup, (ag, blowup)
    # unrolled ring: (P-1) size-1 permutes of one padded Y shard each —
    # the whole of Y crosses the wire at most once
    ring_budget = (By + 8) * D * 4          # B_y_padded * D * 4 bytes
    assert st.by_kind["collective-permute"][2] <= ring_budget, \\
        (st.by_kind, ring_budget)

    # end to end through the signature legs: ragged Gram + MMD
    X = jnp.cumsum(jax.random.normal(jax.random.PRNGKey(5), (10, 9, 2)), 1)
    Y = jnp.cumsum(jax.random.normal(jax.random.PRNGKey(6), (7, 9, 2)), 1)
    xl = jnp.asarray([9, 4, 2, 9, 1, 6, 3, 8, 9, 5], jnp.int32)
    ref_K = sig_gram(X, Y, 3, route="oracle", backend="jax", x_lengths=xl)
    ref_m = sig_mmd(X, Y, 3, backend="jax", x_lengths=xl)
    gref = jax.grad(lambda a: sig_mmd(a, Y, 3, backend="jax",
                                      x_lengths=xl))(X)
    with sharding_ctx(mesh):
        K = sig_gram(X, Y, 3, backend="jax", x_lengths=xl)
        m = sig_mmd(X, Y, 3, backend="jax", x_lengths=xl)
        gm = jax.grad(lambda a: sig_mmd(a, Y, 3, backend="jax",
                                        x_lengths=xl))(X)
    np.testing.assert_allclose(np.asarray(K), np.asarray(ref_K),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(m), np.asarray(ref_m),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gm), np.asarray(gref),
                               rtol=1e-4, atol=1e-5)
    print("SHARDOK gram")
""")

_TRAIN_SERVE = _PRELUDE + textwrap.dedent("""
    import dataclasses
    import repro.models as M
    from repro.configs import get_config, reduce_config
    from repro.core.signature import signature
    from repro.models.sig_head import SigHeadConfig
    from repro.optim import adamw
    from repro.serve import DynamicBatcher
    from repro.train import TrainLoopConfig, train_loop

    cfg = reduce_config(get_config("qwen3-4b"))
    cfg = dataclasses.replace(cfg, sig_head=SigHeadConfig(
        depth=3, channels=2, backend="jax"))
    loop = TrainLoopConfig(steps=3, log_every=1, loss="sig_mmd")

    def make_iter(seed=0):
        rng = np.random.default_rng(seed)
        while True:
            yield {"tokens": jnp.asarray(rng.integers(
                       1, cfg.vocab_size, (8, 16)), jnp.int32),
                   "paths": jnp.asarray(np.cumsum(rng.normal(
                       size=(8, 17, 2)).astype(np.float32), 1) * 0.3)}

    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    with sharding_ctx(mesh):
        _, _, hist_dp = train_loop(cfg, params, adamw(lr=1e-3),
                                   make_iter(), loop)
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    _, _, hist_1 = train_loop(cfg, params, adamw(lr=1e-3),
                              make_iter(), loop)
    for a, b in zip(hist_dp, hist_1):
        assert np.isfinite(a["loss"])
        assert abs(a["loss"] - b["loss"]) < 1e-4 * max(1.0, abs(b["loss"])), \\
            (a["loss"], b["loss"])
    print("ok trainer", flush=True)

    rng = np.random.default_rng(1)
    reqs = [np.cumsum(rng.normal(size=(L + 1, 2)).astype(np.float32), 0)
            for L in (5, 40, 12, 3, 63, 21, 9, 2, 31, 17)]
    db = DynamicBatcher.signature_service(2, 3, max_len=64, backend="jax",
                                          min_bucket=8, max_batch=16,
                                          mesh=mesh)
    tickets = [db.submit(r) for r in reqs]
    res = db.flush()
    for t, r in zip(tickets, reqs):
        ref = signature(jnp.asarray(r)[None], 3)[0]
        np.testing.assert_allclose(np.asarray(res[t]), np.asarray(ref),
                                   atol=1e-5)
    st = db.stats()
    assert st["devices"] == 8 and st["rows_per_device"] >= 1, st
    assert 0.0 < st["occupancy"] <= 1.0, st
    for rung, Bp in st["shapes"]:
        assert Bp % 8 == 0, st["shapes"]    # every device owns equal rows
    print("SHARDOK trainserve")
""")

_OBS = _PRELUDE + textwrap.dedent("""
    # observability under shard_map: dispatch spans + counters fire for
    # mesh-routed calls, and the gram ring's ANALYTIC wire-byte counter
    # agrees with the lowered HLO via collective_stats (the double-buffered
    # ring is unrolled: size-1 permute instructions, one shard each, so
    # analytic == total permute wire bytes).
    from repro import obs

    obs.enable()
    obs.start_trace()
    with sharding_ctx(mesh):
        ops.signature(x, depth, backend="jax").block_until_ready()
    assert obs.counter("pathsig_dispatch_calls_total", "",
                       ("op", "backend", "ctx")).value(
        op="signature", backend="jax", ctx="eager") >= 1
    evs = [e for e in obs.trace.TRACER.events
           if e.get("name") == "kernels.signature"]
    assert evs and evs[0]["args"]["ctx"] == "eager", evs[:3]
    obs.stop_trace()

    # gram ring wire accounting: By divisible by n_dev -> no pad rows, the
    # analytic counter is exactly (n_dev - 1) * shard_bytes per eager call
    # (the final ring step consumes the prefetched shard without another
    # permute)
    obs.reset()
    Bx, By, D = 16, 24, 120
    Sx = jax.random.normal(jax.random.PRNGKey(1), (Bx, D))
    Sy = jax.random.normal(jax.random.PRNGKey(2), (By, D))
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (D,))) + 0.1
    with sharding_ctx(mesh):
        ops.gram(Sx, Sy, w, backend="jax").block_until_ready()
    wire_counter = obs.counter("pathsig_ring_wire_bytes_total", "",
                               ("ctx",))
    analytic = wire_counter.value(ctx="eager")
    assert analytic == 7 * (By // 8) * D * 4, analytic

    with sharding_ctx(mesh):
        txt = jax.jit(lambda a, b, c: ops.gram(a, b, c, backend="jax")
                      ).lower(Sx, Sy, w).compile().as_text()
    st = collective_stats(txt, default_group=8)
    n, _, wire = st.by_kind["collective-permute"]
    assert n == 7, st.by_kind           # unrolled: one instr per ring step
    assert analytic == wire, (analytic, n, wire)
    print("SHARDOK obs")
""")

_RETRACE = _PRELUDE + textwrap.dedent("""
    # the efficiency-cliff contract, on lowered artifacts:
    # 1. retrace-free dispatch: repeated same-shape mesh calls across the
    #    weak-scaling sweep compile each sharded site at most ONCE per
    #    (site, shape key) — the per-shard closures are hoisted into
    #    plan-cached callables, so the jit cache does the rest;
    # 2. the data-parallel train step actually ALIASES its donated
    #    (params, opt_state) buffers (hlo.assert_donation);
    # 3. the double-buffered gram ring lowers to an unrolled, overlappable
    #    schedule (hlo.ring_overlap): permutes outside any while loop and
    #    never data-dependent on the tile dots.
    import dataclasses
    from repro import obs
    from repro.distributed import hlo

    obs.enable()
    obs.reset()
    D = 120
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (D,))) + 0.1
    for P in (2, 4, 8):
        m = make_sig_mesh(P)
        xp = jax.random.normal(jax.random.PRNGKey(7), (4 * P, M, d)) * 0.2
        Sp = jax.random.normal(jax.random.PRNGKey(8), (2 * P, D))
        with sharding_ctx(m):
            for _ in range(3):
                ops.signature(xp, depth, backend="jax").block_until_ready()
                jax.grad(lambda a: ops.signature(
                    a, depth, backend="jax").sum())(xp).block_until_ready()
                ops.projected(xp, words, backend="jax").block_until_ready()
                jax.grad(lambda a: ops.projected(
                    a, words, backend="jax").sum())(xp).block_until_ready()
                ops.gram(Sp, Sp, w, backend="jax").block_until_ready()

    sharded = {"sharded_sig", "sharded_proj", "sharded_proj_fwd",
               "gram_ring"}
    snap = obs.snapshot()["metrics"]["pathsig_jit_traces_total"]["values"]
    rows = [v for v in snap if v["labels"]["site"] in sharded]
    assert {r["labels"]["site"] for r in rows} >= {"sharded_sig",
                                                   "gram_ring"}, rows
    bad = [r for r in rows if r["value"] != 1]
    assert not bad, ("retraced sharded sites", bad)
    print("ok retrace-free", len(rows), "site/shape keys", flush=True)

    # 2. donation on the lowered data-parallel train step
    import repro.models as MM
    from repro.configs import get_config, reduce_config
    from repro.models.sig_head import SigHeadConfig
    from repro.optim import adamw
    from repro.train.trainer import make_train_step

    cfg = reduce_config(get_config("qwen3-4b"))
    cfg = dataclasses.replace(cfg, sig_head=SigHeadConfig(
        depth=3, channels=2, backend="jax"))
    params = MM.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    opt = adamw(lr=1e-3)
    opt_state = opt.init(params)
    batch = {"tokens": jnp.ones((8, 16), jnp.int32),
             "paths": jnp.ones((8, 17, 2), jnp.float32)}
    step = obs.instrument_jit(make_train_step(cfg, opt, loss="sig_mmd"),
                              site="train_step_hlo", donate_argnums=(0, 1))
    txt = step.lower(params, opt_state, batch).compile().as_text()
    st = hlo.assert_donation(txt, min_aliased=2)
    print("ok donation:", st.n_aliased, "aliased pairs", flush=True)

    # 3. overlap structure of the lowered ring
    Sx = jax.random.normal(jax.random.PRNGKey(1), (16, D))
    with sharding_ctx(mesh):
        rtxt = jax.jit(lambda a, b, c: ops.gram(a, b, c, backend="jax")
                       ).lower(Sx, Sx, w).compile().as_text()
    ov = hlo.ring_overlap(rtxt)
    assert ov.overlapped, ov.summary()
    assert ov.n_permutes == 7, ov.summary()
    print("ok ring overlap:", ov.summary(), flush=True)
    print("SHARDOK retrace")
""")

_SCRIPTS = {"truncated": (_TRUNCATED, "SHARDOK truncated"),
            "projected": (_PROJECTED, "SHARDOK projected"),
            "gram": (_GRAM, "SHARDOK gram"),
            "trainserve": (_TRAIN_SERVE, "SHARDOK trainserve"),
            "obs": (_OBS, "SHARDOK obs"),
            "retrace": (_RETRACE, "SHARDOK retrace")}


@pytest.mark.parametrize("name", sorted(_SCRIPTS))
def test_sharded_equivalence_subprocess(name):
    script, sentinel = _SCRIPTS[name]
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=420)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    assert sentinel in r.stdout


# ---------------------------------------------------------------------------
# in-process: single-device no-op guarantees, cache bounding, mesh helpers
# ---------------------------------------------------------------------------

def test_one_shard_context_is_bit_identical():
    """A context whose batch axis has a single shard never takes the mesh
    branch — outputs and grads match the no-context path to the bit."""
    import jax
    import jax.numpy as jnp
    from repro.distributed import sharding_ctx
    from repro.kernels import ops
    from repro.launch.mesh import make_sig_mesh

    x = jax.random.normal(jax.random.PRNGKey(0), (4, 10, 2)) * 0.3
    lens = jnp.asarray([10, 3, 7, 0], jnp.int32)
    mesh = make_sig_mesh(1)
    for kwargs in ({}, {"stream": True, "stream_stride": 4},
                   {"lengths": lens}, {"backward": "checkpoint"}):
        ref = ops.signature(x, 3, backend="pallas_interpret", **kwargs)
        gref = jax.grad(lambda a: ops.signature(
            a, 3, backend="pallas_interpret", **kwargs).sum())(x)
        with sharding_ctx(mesh):
            got = ops.signature(x, 3, backend="pallas_interpret", **kwargs)
            ggot = jax.grad(lambda a: ops.signature(
                a, 3, backend="pallas_interpret", **kwargs).sum())(x)
        assert (np.asarray(got) == np.asarray(ref)).all()
        assert (np.asarray(ggot) == np.asarray(gref)).all()


def test_make_dev_mesh_validates_device_count():
    from repro.launch.mesh import make_dev_mesh, make_sig_mesh

    with pytest.raises(ValueError, match="devices"):
        make_dev_mesh(data=64, model=64)
    with pytest.raises(ValueError, match="devices"):
        make_sig_mesh(batch=4096)
    with pytest.raises(ValueError, match=">= 1"):
        make_sig_mesh(batch=0)
    with pytest.raises(ValueError, match=">= 1"):
        make_dev_mesh(data=0)
    assert tuple(make_sig_mesh(1).axis_names) == ("data",)


def test_plan_caches_bounded_eviction_and_clear():
    """A maxsize-1 plan-cache policy forces eviction on every alternation of
    word sets — results must be identical to the unbounded policy, and
    clear_plan_caches() must be a pure perf event."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    x = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 2)) * 0.3
    sets = [((0,), (1, 0)), ((1,), (0, 1), (1, 1, 0)), ((0, 0), (1, 0, 1))]
    ref = [np.asarray(ops.projected(x, ws, backend="pallas_interpret"))
           for ws in sets]
    gref = [np.asarray(jax.grad(lambda a, ws=ws: ops.projected(
        a, ws, backend="pallas_interpret").sum())(x)) for ws in sets]

    old = ops.PLAN_CACHE_MAXSIZE
    try:
        ops.set_plan_cache_maxsize(1)
        for _ in range(2):              # alternate -> evict every call
            for i, ws in enumerate(sets):
                got = np.asarray(ops.projected(x, ws,
                                               backend="pallas_interpret"))
                np.testing.assert_array_equal(got, ref[i])
                ggot = np.asarray(jax.grad(lambda a, ws=ws: ops.projected(
                    a, ws, backend="pallas_interpret").sum())(x))
                np.testing.assert_array_equal(ggot, gref[i])
        info = ops.plan_cache_info()
        assert info["_pallas_proj_inverse"].maxsize == 1
        assert info["_pallas_proj_inverse"].misses > len(sets)  # evictions
        ops.clear_plan_caches()
        assert ops.plan_cache_info()["_plan_for_words"].currsize == 0
        got = np.asarray(ops.projected(x, sets[0],
                                       backend="pallas_interpret"))
        np.testing.assert_array_equal(got, ref[0])
    finally:
        ops.set_plan_cache_maxsize(old)


def test_plan_cache_policy_is_shared():
    """Every registered cache follows the configured bound."""
    from repro.kernels import ops

    old = ops.PLAN_CACHE_MAXSIZE
    try:
        ops.set_plan_cache_maxsize(7)
        info = ops.plan_cache_info()
        assert info, "no plan caches registered"
        assert all(ci.maxsize == 7 for ci in info.values()), info
        for name in ("_plan_for_words", "_tiled_for_words", "_gram_vjp",
                     "_pallas_sig_inverse", "_sharded_sig", "_gram_ring"):
            assert name in info, sorted(info)
    finally:
        ops.set_plan_cache_maxsize(old)
