"""Windowed-signature tests (paper §5): one-call batch == per-window loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core as C
from repro.core import (dyadic_windows, expanding_windows, sliding_windows,
                        windowed_projection, windowed_signature,
                        windowed_signature_chen)
from repro.core.words import make_plan
from tests.conftest import make_path


def _oracle(path, windows, N):
    return np.stack([np.asarray(C.signature(path[:, l:r + 1], N))
                     for l, r in windows], axis=1)  # noqa: E741


def test_matches_per_window_oracle(rng):
    path = make_path(rng, 3, 20, 3)
    windows = np.asarray([[0, 20], [0, 5], [5, 12], [11, 20], [7, 8]],
                         np.int32)
    out = windowed_signature(jnp.asarray(path), windows, 3)
    np.testing.assert_allclose(out, _oracle(path, windows, 3),
                               rtol=1e-4, atol=1e-5)


def test_chen_route_agrees(rng):
    path = make_path(rng, 2, 24, 3)
    windows = sliding_windows(24, 8, stride=4)
    a = windowed_signature(jnp.asarray(path), windows, 3)
    b = windowed_signature_chen(jnp.asarray(path), windows, 3)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_expanding_windows_equal_stream(rng):
    path = jnp.asarray(make_path(rng, 2, 10, 2))
    wins = expanding_windows(10)
    ws = windowed_signature(path, wins, 3)
    stream = C.signature(path, 3, stream=True)
    np.testing.assert_allclose(ws, stream, rtol=1e-4, atol=1e-5)


def test_windowed_projection_subset(rng):
    d = 3
    path = jnp.asarray(make_path(rng, 2, 16, d))
    windows = np.asarray([[0, 8], [4, 16]], np.int32)
    words = [(0,), (2, 1), (1, 1, 0)]
    plan = make_plan(words, d)
    proj = windowed_projection(path, windows, plan)
    full = windowed_signature(path, windows, 3)
    idx = [C.flat_index(w, d) for w in words]
    np.testing.assert_allclose(proj, full[..., idx], rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("tr", ["time_augment", "lead_lag", "basepoint",
                                "time_augment+lead_lag"])
def test_windowed_transform_matches_per_window_oracle(rng, tr):
    """transform= applies PER WINDOW, fused into the fold route's sweep:
    identical to signature(window_slice, transform=...) for every window
    (time restarts per window, basepoint is each window's first value)."""
    path = make_path(rng, 3, 20, 2)
    windows = np.asarray([[0, 20], [0, 5], [5, 12], [11, 20], [7, 8]],
                         np.int32)
    out = windowed_signature(jnp.asarray(path), windows, 3, transform=tr)
    ref = np.stack([np.asarray(C.signature(path[:, l:r + 1], 3,
                                           transform=tr))
                    for l, r in windows], axis=1)  # noqa: E741
    assert out.shape == ref.shape
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


def test_windowed_transform_projection_subset(rng):
    d = 2
    path = jnp.asarray(make_path(rng, 2, 16, d))
    windows = np.asarray([[0, 8], [4, 16]], np.int32)
    from repro.core.transforms import transform_dim
    d_aug = transform_dim("lead_lag", d)
    words = [(0,), (2, 1), (1, 3, 0)]
    plan = make_plan(words, d_aug)
    proj = windowed_projection(path, windows, plan, transform="lead_lag")
    full = windowed_signature(path, windows, 3, transform="lead_lag")
    idx = [C.flat_index(w, d_aug) for w in words]
    np.testing.assert_allclose(proj, full[..., idx], rtol=1e-4, atol=1e-5)


def test_windowed_transform_pins_route_to_fold(rng):
    """Per-window transform semantics don't compose with Chen combination
    of one streamed pass (the streamed pass transforms the WHOLE path):
    an explicit route="chen" refuses, route="auto" silently takes fold."""
    path = jnp.asarray(make_path(rng, 1, 12, 2))
    windows = np.asarray([[0, 6], [3, 12]], np.int32)
    with pytest.raises(NotImplementedError, match="chen"):
        windowed_signature(path, windows, 3, transform="time_augment",
                           route="chen")
    out = windowed_signature(path, windows, 3, transform="time_augment",
                             route="auto")
    assert out.shape[1] == 2


def test_windowed_transform_ragged_clipping(rng):
    """With lengths, window [l, r] clips to [min(l, L_b), min(r, L_b)] per
    example BEFORE the transform applies — the time channel and basepoint
    see the clipped window, exactly like the per-example oracle."""
    path = make_path(rng, 3, 14, 2)
    lens = np.asarray([14, 9, 3], np.int32)
    windows = np.asarray([[0, 14], [2, 11], [5, 6]], np.int32)
    out = windowed_signature(jnp.asarray(path), windows, 3,
                             transform="time_augment+basepoint",
                             lengths=jnp.asarray(lens))
    for b, L in enumerate(lens):
        for k, (l, r) in enumerate(windows):  # noqa: E741
            lb, rb = min(l, L), min(r, L)
            ref = np.asarray(C.signature(
                path[b:b + 1, lb:rb + 1], 3,
                transform="time_augment+basepoint"))[0]
            np.testing.assert_allclose(np.asarray(out[b, k]), ref,
                                       rtol=1e-4, atol=1e-5,
                                       err_msg=f"b={b} window=({l},{r})")


def test_gradients_flow_through_windows(rng):
    path = jnp.asarray(make_path(rng, 2, 12, 2))
    windows = np.asarray([[0, 6], [3, 12]], np.int32)
    g = jax.grad(lambda p: jnp.sum(windowed_signature(p, windows, 3) ** 2))(
        path)
    assert g.shape == path.shape and bool(jnp.all(jnp.isfinite(g)))
    # increments outside every window get zero path-gradient contribution:
    # here steps 0..5 and 3..11 cover everything except nothing -> nonzero
    assert float(jnp.max(jnp.abs(g))) > 0


def test_window_helpers():
    ew = expanding_windows(10, stride=2)
    assert (ew[:, 0] == 0).all() and list(ew[:, 1]) == [2, 4, 6, 8, 10]
    sw = sliding_windows(10, 4, stride=3)
    assert [tuple(w) for w in sw] == [(0, 4), (3, 7), (6, 10)]
    dw = dyadic_windows(8, 3)
    assert (dw[:, 1] > dw[:, 0]).all()
    assert tuple(dw[0]) == (0, 8)          # level 0: the whole interval
    assert len(dw) == 1 + 2 + 4


def test_expanding_windows_keeps_path_tail():
    """stride ∤ M used to silently drop the tail: [0, M] must always close."""
    ew = expanding_windows(10, stride=3)
    assert list(ew[:, 1]) == [3, 6, 9, 10]
    assert tuple(ew[-1]) == (0, 10)
    # stride > M degenerates to the single full window
    assert [tuple(w) for w in expanding_windows(4, stride=7)] == [(0, 4)]
    with pytest.raises(ValueError):
        expanding_windows(0)


def test_sliding_windows_validates_length():
    with pytest.raises(ValueError, match="length"):
        sliding_windows(8, 9)              # length > M used to yield 0 windows
    with pytest.raises(ValueError, match="length"):
        sliding_windows(8, 0)
    with pytest.raises(ValueError, match="stride"):
        sliding_windows(8, 4, stride=0)
    assert [tuple(w) for w in sliding_windows(8, 8)] == [(0, 8)]


def test_empty_window_set_returns_empty_result(rng):
    """Used to crash with 'zero-size array to reduction operation maximum'."""
    path = jnp.asarray(make_path(rng, 2, 10, 3))
    out = windowed_signature(path, np.zeros((0, 2), np.int32), 3)
    assert out.shape == (2, 0, C.sig_dim(3, 3))
    plan = make_plan([(0,), (2, 1)], 3)
    proj = windowed_projection(path, np.zeros((0, 2), np.int32), plan)
    assert proj.shape == (2, 0, 2)


def test_out_of_range_windows_raise(rng):
    path = jnp.asarray(make_path(rng, 1, 10, 2))
    with pytest.raises(ValueError, match="window indices"):
        windowed_signature(path, np.asarray([[0, 11]], np.int32), 2)
    with pytest.raises(ValueError, match="l <= r"):
        windowed_signature(path, np.asarray([[5, 3]], np.int32), 2)


@given(st.integers(2, 3), st.integers(1, 3),
       st.lists(st.tuples(st.integers(0, 10), st.integers(1, 14)),
                min_size=1, max_size=5))
@settings(max_examples=12, deadline=None)
def test_random_windows_property(d, N, raw_windows):
    windows = np.asarray([(min(a, b - 1) if a < b else b - 1, b)
                          for a, b in raw_windows
                          if b >= 1], np.int32)
    windows[:, 0] = np.clip(windows[:, 0], 0, None)
    if len(windows) == 0:
        return
    rng = np.random.default_rng(d * 10 + N)
    path = make_path(rng, 2, 14, d)
    out = windowed_signature(jnp.asarray(path), windows, N)
    np.testing.assert_allclose(out, _oracle(path, windows, N),
                               rtol=2e-4, atol=1e-5)


def test_single_point_window_is_identity_signature(rng):
    """A window of length 1 covers a single increment; length 0 is empty."""
    path = jnp.asarray(make_path(rng, 1, 10, 2))
    windows = np.asarray([[4, 5]], np.int32)
    out = windowed_signature(path, windows, 2)
    seg = C.signature(path[:, 4:6], 2)
    np.testing.assert_allclose(out[:, 0], seg, rtol=1e-5, atol=1e-6)


def test_auto_route_within_15pct_of_best_on_fig3_grid():
    """Cost-model calibration regression (satellite of the perf PR): on every
    committed BENCH_fig3.json measurement, the route ``select_route("auto")``
    picks must be within 15% of the measured-best fixed route.  Catches
    constant drift: if someone retunes _CHEN_STEP_COST / _CHEN_ADVANTAGE into
    a regime the measured grid contradicts, this fails without ever running
    a benchmark."""
    import json
    import pathlib

    from repro.core.windows import select_route

    bench = pathlib.Path(__file__).resolve().parent.parent / "BENCH_fig3.json"
    if not bench.exists():
        pytest.skip("no committed BENCH_fig3.json")
    records = json.loads(bench.read_text())["records"]
    assert records, "BENCH_fig3.json has no records"
    for rec in records:
        windows = sliding_windows(rec["M"], rec["wlen"], rec["stride"])
        assert windows.shape[0] == rec["K"], (
            f"window grid drifted: rebuilt K={windows.shape[0]} != "
            f"recorded K={rec['K']}")
        route = select_route("auto", windows, rec["M"])
        measured = {"fold": rec["fold_ms"], "chen": rec["chen_ms"]}
        best = min(measured.values())
        assert measured[route] <= 1.15 * best, (
            f"auto picked {route} ({measured[route]:.2f} ms) but best fixed "
            f"route costs {best:.2f} ms on {rec}")
