"""MoE grouped-dispatch correctness (the §Perf cell-A engine).

The grouped capacity dispatch (GShard-style) must agree exactly with a
dense dropless reference when capacity is ample, must drop deterministically
when it is not, and must keep prefill == decode parity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import layers as L

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = reduce_config(get_config("phi3.5-moe-42b-a6.6b"))
    return dataclasses.replace(base, **kw)


def _dense_reference(p, x, cfg):
    """Dropless oracle: every token through its top-k experts, dense loop."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = xt @ p["router"].astype(x.dtype)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)
    out = jnp.zeros((T, d), jnp.float32)
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.act]
    for e in range(cfg.n_experts):
        g = xt @ p["w_gate"][e].astype(x.dtype)
        u = xt @ p["w_up"][e].astype(x.dtype)
        y = (act(g) * u) @ p["w_down"][e].astype(x.dtype)
        for k in range(cfg.top_k):
            w = jnp.where(gate_idx[:, k] == e, gate_vals[:, k], 0.0)
            out = out + w[:, None] * y.astype(jnp.float32)
    if cfg.n_shared_experts:
        out = out + L.mlp(p["shared"], x, cfg.act).reshape(T, d)
    return out.reshape(B, S, d).astype(x.dtype)


@pytest.mark.parametrize("group", [0, 8, 16])
def test_grouped_dispatch_matches_dropless_reference(group):
    cfg = _cfg(capacity_factor=8.0, moe_group_size=group)  # ample capacity
    B, S = 2, 16
    p = L.init_moe(jax.random.split(KEY)[0], cfg)
    x = jax.random.normal(jax.random.split(KEY)[1], (B, S, cfg.d_model),
                          jnp.float32) * 0.3
    got, aux = L.moe(p, x, cfg)
    want = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux))


def test_small_token_counts_are_dropless():
    """T <= 4E uses one dropless group: prefill == sum of decode steps."""
    cfg = _cfg()
    p = L.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (1, 3, cfg.d_model), jnp.float32) * 0.3
    full, _ = L.moe(p, x, cfg)
    stepwise = jnp.concatenate(
        [L.moe(p, x[:, i:i + 1], cfg)[0] for i in range(3)], axis=1)
    np.testing.assert_allclose(full, stepwise, rtol=1e-4, atol=1e-5)


def test_tight_capacity_drops_but_stays_finite():
    cfg = _cfg(capacity_factor=0.5, moe_group_size=8)
    p = L.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model), jnp.float32)
    out, aux = L.moe(p, x, cfg)
    ref = _dense_reference(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))
    # capacity drops make the output differ from dropless — by construction
    assert float(jnp.max(jnp.abs(out - ref))) > 0


def test_grouped_dispatch_gradients_flow():
    cfg = _cfg(capacity_factor=2.0, moe_group_size=8)
    p = L.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32) * 0.3

    def loss(p):
        out, aux = L.moe(p, x, cfg)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert bool(jnp.all(jnp.isfinite(leaf))), path
    assert float(jnp.max(jnp.abs(g["w_up"]))) > 0
