"""Projection / anisotropic / windows / log-signature behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core import tensor_ops as tops
from repro.core.words import flat_index, make_plan
from tests.conftest import make_path


def test_projection_matches_truncation_subset(rng):
    d, N = 3, 4
    path = make_path(rng, 3, 10, d)
    dense = C.signature(path, N)
    words = [(0,), (2, 2), (1, 0, 2), (0, 1, 2, 0)]
    proj = C.projected_signature(path, words, d)
    for k, w in enumerate(words):
        np.testing.assert_allclose(proj[:, k], dense[:, flat_index(w, d)],
                                   rtol=1e-4, atol=1e-5)


def test_dag_projection(rng):
    d = 3
    ws = C.dag_words([(0, 1), (1, 2), (2, 0)], d, 3)
    path = make_path(rng, 2, 8, d)
    proj = C.projected_signature(path, ws, d)
    dense = C.signature(path, 3)
    for k, w in enumerate(ws):
        np.testing.assert_allclose(proj[:, k], dense[:, flat_index(w, d)],
                                   rtol=1e-4, atol=1e-5)


def test_anisotropic_signature(rng):
    """Def. 7.1: anisotropic = projection onto W^γ_{<=r}."""
    gamma, r, d = [1.0, 2.0], 4.0, 2
    ws = C.anisotropic_words(gamma, r)
    path = make_path(rng, 2, 9, d)
    proj = C.projected_signature(path, ws, d)
    dense = C.signature(path, 4)
    for k, w in enumerate(ws):
        np.testing.assert_allclose(proj[:, k], dense[:, flat_index(w, d)],
                                   rtol=1e-4, atol=1e-5)
    # uniform weights + integer cutoff reduce to plain truncation
    ws_unif = C.anisotropic_words([1.0] * d, 3.0)
    assert set(ws_unif) == set(C.all_words(d, 3))


def test_logsignature_dense_vs_projected(rng):
    for d, N in [(2, 4), (3, 3), (2, 6)]:
        path = make_path(rng, 2, 12, d)
        a = C.logsignature(path, N)
        b = C.logsignature_projected(path, N)
        assert a.shape == (2, C.logsig_dim(d, N))
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)


def test_logsignature_level1_is_increment(rng):
    path = make_path(rng, 2, 9, 3)
    ls = C.logsignature(path, 3)
    np.testing.assert_allclose(ls[:, :3], path[:, -1] - path[:, 0],
                               rtol=1e-5, atol=1e-6)


def test_logsignature_bch_two_segments():
    """log sig of two segments = BCH(a, b): check level 2 = [a,b]/2."""
    a = np.array([0.3, -0.2], np.float32)
    b = np.array([0.1, 0.4], np.float32)
    path = np.stack([np.zeros(2, np.float32), a, a + b])[None]
    ls = C.logsignature(jnp.asarray(path), 2)
    # Lyndon basis at d=2, N=2: words (0,), (1,), (0,1)
    np.testing.assert_allclose(ls[0, :2], a + b, rtol=1e-5, atol=1e-6)
    area = 0.5 * (a[0] * b[1] - a[1] * b[0])
    np.testing.assert_allclose(ls[0, 2], area, rtol=1e-4, atol=1e-6)


def test_logsig_gradients(rng):
    path = jnp.asarray(make_path(rng, 1, 7, 2))

    def loss_a(p):
        return jnp.sum(C.logsignature(p, 4) ** 2)

    def loss_b(p):
        return jnp.sum(C.logsignature_projected(p, 4) ** 2)

    ga, gb = jax.grad(loss_a)(path), jax.grad(loss_b)(path)
    np.testing.assert_allclose(ga, gb, rtol=1e-3, atol=1e-4)


def test_windowed_signature_matches_slices(rng):
    path = make_path(rng, 3, 25, 2)
    wins = np.array([[0, 25], [3, 9], [9, 25], [24, 25]], np.int32)
    out = C.windowed_signature(path, wins, 3)
    for k, (l, r) in enumerate(wins):
        np.testing.assert_allclose(out[:, k], C.signature(path[:, l:r + 1], 3),
                                   rtol=1e-4, atol=1e-5)


def test_windowed_chen_route_agrees(rng):
    path = make_path(rng, 2, 20, 2)
    wins = C.sliding_windows(20, 5, 3)
    a = C.windowed_signature(path, wins, 3)
    b = C.windowed_signature_chen(path, wins, 3)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_window_helpers():
    e = C.expanding_windows(10, 2)
    assert (e[:, 0] == 0).all() and list(e[:, 1]) == [2, 4, 6, 8, 10]
    s = C.sliding_windows(10, 4, 2)
    assert [tuple(x) for x in s] == [(0, 4), (2, 6), (4, 8), (6, 10)]
    dy = C.dyadic_windows(8, 3)
    assert (dy[:, 1] > dy[:, 0]).all()


def test_windowed_projection(rng):
    d = 2
    plan = make_plan([(0,), (1, 0), (0, 1, 1)], d)
    path = make_path(rng, 2, 16, d)
    wins = np.array([[0, 8], [4, 16]], np.int32)
    out = C.windowed_projection(path, wins, plan)
    for k, (l, r) in enumerate(wins):
        want = C.projected_signature(path[:, l:r + 1], plan.words, d, plan=plan)
        np.testing.assert_allclose(out[:, k], want, rtol=1e-4, atol=1e-5)


def test_lead_lag_quadratic_variation(rng):
    """§8: the lead-lag level-2 area encodes discrete quadratic variation."""
    d, M = 1, 50
    path = make_path(rng, 1, M, d, scale=0.2)
    ll = C.lead_lag(path)                       # channels: [lag, lead]
    s = C.signature(ll, 2)
    lvl2 = np.asarray(s[:, 2:]).reshape(1, 2, 2)
    qv = float(np.sum(np.diff(path[0, :, 0]) ** 2))
    # antisymmetric part of (lag, lead) block = QV / 2
    area = float(lvl2[0, 1, 0] - lvl2[0, 0, 1])
    np.testing.assert_allclose(area, qv, rtol=1e-3, atol=1e-5)
