"""Tests for the repro.sigkernel subsystem: weighted/projected Gram matrices,
MMD, low-rank features, KRR + the serving/model/training integrations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.sigkernel as SK
from repro.core import anisotropic_words, sig_dim
from repro.core import tensor_ops as tops
from repro.kernels import ops


def make_path(rng, B, M, d, scale=0.3):
    return jnp.asarray(np.cumsum(rng.normal(size=(B, M + 1, d)) * scale,
                                 axis=1).astype(np.float32))


# ---------------------------------------------------------------------------
# sig_gram: tiled/Pallas routes vs the naive oracle (acceptance 1e-5 fp32)
# ---------------------------------------------------------------------------

GRAM_BACKENDS = ("jax", "pallas_interpret")


@pytest.mark.parametrize("backend", GRAM_BACKENDS)
def test_gram_truncated_matches_oracle(rng, backend):
    x = make_path(rng, 7, 30, 3)
    y = make_path(rng, 5, 22, 3)
    ref = np.asarray(SK.sig_gram(x, y, 4, route="oracle", backend="jax"))
    got = np.asarray(SK.sig_gram(x, y, 4, route="tiled", backend=backend,
                                 block_words=64))
    np.testing.assert_allclose(got, ref, atol=1e-5 * np.abs(ref).max())


@pytest.mark.parametrize("backend", GRAM_BACKENDS)
def test_gram_projected_words_matches_oracle(rng, backend):
    x = make_path(rng, 6, 25, 3)
    y = make_path(rng, 4, 25, 3)
    words = anisotropic_words((1.0, 1.0, 2.0), 4.0)
    ref = np.asarray(SK.sig_gram(x, y, words=words, route="oracle",
                                 backend="jax"))
    got = np.asarray(SK.sig_gram(x, y, words=words, route="tiled",
                                 backend=backend, block_words=16))
    assert ref.shape == (6, 4)
    np.testing.assert_allclose(got, ref, atol=1e-5 * np.abs(ref).max())


@pytest.mark.parametrize("backend", GRAM_BACKENDS)
def test_gram_anisotropic_weights_matches_oracle(rng, backend):
    x = make_path(rng, 6, 20, 3)
    kw = dict(gamma=(0.5, 1.0, 2.0), level_weights=(1.0, 0.5, 0.25, 0.125))
    ref = np.asarray(SK.sig_gram(x, None, 4, route="oracle", backend="jax",
                                 **kw))
    got = np.asarray(SK.sig_gram(x, None, 4, route="tiled", backend=backend,
                                 block_words=48, **kw))
    np.testing.assert_allclose(got, ref, atol=1e-5 * np.abs(ref).max())
    # symmetric input -> symmetric Gram
    np.testing.assert_allclose(got, got.T, atol=1e-5 * np.abs(ref).max())


def test_gram_weight_semantics_channel_scaling(rng):
    """ω_w = Π γ_{w_j} equals scaling path channel i by √γ_i (paper §7.2)."""
    x = make_path(rng, 4, 18, 3)
    y = make_path(rng, 4, 18, 3)
    gamma = (0.5, 1.3, 2.0)
    K = SK.sig_gram(x, y, 3, gamma=gamma)
    scale = jnp.sqrt(jnp.asarray(gamma))[None, None, :]
    K2 = SK.sig_gram(x * scale, y * scale, 3)
    np.testing.assert_allclose(np.asarray(K), np.asarray(K2),
                               atol=1e-5 * float(jnp.abs(K).max()))


def test_gram_explicit_weight_vector(rng):
    x = make_path(rng, 5, 16, 2)
    D = sig_dim(2, 3)
    w = jnp.asarray(np.random.default_rng(1).uniform(0.1, 2.0, D)
                    .astype(np.float32))
    K = SK.sig_gram(x, None, 3, weights=w, block_words=7)
    S = SK.signature_features(x, 3)
    ref = (S * w[None]) @ S.T
    np.testing.assert_allclose(np.asarray(K), np.asarray(ref), atol=1e-5)


def test_gram_psd(rng):
    """K = S diag(ω) Sᵀ with ω > 0 must be PSD for any path batch."""
    x = make_path(rng, 10, 24, 3)
    for kw in (dict(), dict(gamma=(0.5, 1.0, 2.0)),
               dict(level_weights=(1.0, 0.5, 0.25))):
        K = np.asarray(SK.sig_gram(x, None, 3, **kw))
        evals = np.linalg.eigvalsh((K + K.T) / 2)
        assert evals.min() >= -1e-5 * max(evals.max(), 1.0), kw


def test_gram_rejects_bad_args(rng):
    x = make_path(rng, 3, 10, 2)
    with pytest.raises(ValueError):
        SK.sig_gram(x, None)                      # neither depth nor words
    with pytest.raises(ValueError):
        SK.sig_gram(x, None, 3, weights=jnp.ones(5), gamma=(1.0, 1.0))
    with pytest.raises(ValueError):
        SK.sig_gram(x, None, 3, route="nope")
    with pytest.raises(ValueError):               # wrong-length weight vector
        SK.sig_gram(x, None, 3, weights=jnp.ones(5))
    with pytest.raises(ValueError):
        SK.word_weights(2, 2, gamma=(1.0, -1.0))
    with pytest.raises(ValueError):
        SK.word_weights(2, 3, level_weights=(1.0, 0.5))  # too short
    with pytest.raises(ValueError):                      # empty word
        SK.word_weights(words=[(), (0,)], level_weights=(0.5,))


def test_gram_product_rejects_shape_mismatch(rng):
    Sx = jnp.asarray(rng.normal(size=(3, 120)).astype(np.float32))
    Sy = jnp.asarray(rng.normal(size=(4, 120)).astype(np.float32))
    for backend in GRAM_BACKENDS:
        with pytest.raises(ValueError):           # weights too short
            ops.gram(Sx, Sy, jnp.ones(80), backend=backend)
        with pytest.raises(ValueError):           # word-dim mismatch
            ops.gram(Sx, Sy[:, :100], jnp.ones(120), backend=backend)


def test_kernel_head_rejects_logsig_combination():
    from repro.configs import get_config, reduce_config, with_sig_head
    from repro.models.sig_head import feature_dim
    cfg = with_sig_head(reduce_config(get_config("qwen3-4b")), channels=2,
                        depth=2, kernel_landmarks=4, use_logsig=True)
    with pytest.raises(NotImplementedError):
        feature_dim(cfg.sig_head)


# ---------------------------------------------------------------------------
# memory law: the tiled route never materialises (B_x, B_y, D_sig)
# ---------------------------------------------------------------------------

def test_gram_tiled_memory_block_sweep(rng):
    """XLA temp bytes of the tiled route stay far below the full
    (B_x, B_y, D_sig) intermediate for every block size in the sweep."""
    Bx, By, d, N = 48, 40, 4, 5
    D = sig_dim(d, N)                       # 1364
    Sx = jnp.asarray(rng.normal(size=(Bx, D)).astype(np.float32))
    Sy = jnp.asarray(rng.normal(size=(By, D)).astype(np.float32))
    w = jnp.ones((D,), jnp.float32)
    full = Bx * By * D * 4                  # ~10.5 MB would-be intermediate

    def temp_bytes(fn, *args):
        compiled = jax.jit(fn).lower(*args).compile()
        mem = compiled.memory_analysis()
        return int(getattr(mem, "temp_size_in_bytes", 0))

    measured = {}
    for block in (64, 128, 341, 1364):
        tb = temp_bytes(lambda a, b, c, blk=block: ops.gram(
            a, b, c, backend="jax", block_words=blk), Sx, Sy, w)
        measured[block] = tb
    if all(tb == 0 for tb in measured.values()):
        pytest.skip("XLA memory_analysis reports no temp bytes here")
    for block, tb in measured.items():
        # O(B_x·B_y + B·block) live state, generous constants + padding slack
        bound = 8 * (Bx * By + (Bx + By) * block) * 4 + 2 ** 20
        assert tb < full / 4, (block, tb, full)
        assert tb < bound, (block, tb, bound)


# ---------------------------------------------------------------------------
# gram product dispatch: gradients (incl. weights) across backends
# ---------------------------------------------------------------------------

def test_gram_product_grads_match_reference(rng):
    Sx = jnp.asarray(rng.normal(size=(5, 37)).astype(np.float32))
    Sy = jnp.asarray(rng.normal(size=(4, 37)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.2, 2.0, 37).astype(np.float32))
    g_ref = jax.grad(lambda a, b, c: jnp.sum(((a * c[None]) @ b.T) ** 2),
                     argnums=(0, 1, 2))(Sx, Sy, w)
    for backend in GRAM_BACKENDS:
        g = jax.grad(lambda a, b, c: jnp.sum(ops.gram(
            a, b, c, backend=backend, block_words=16) ** 2),
            argnums=(0, 1, 2))(Sx, Sy, w)
        for got, ref in zip(g, g_ref):
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# MMD: statistic + differentiability across backends (acceptance)
# ---------------------------------------------------------------------------

def test_mmd_zero_on_identical_samples(rng):
    x = make_path(rng, 6, 20, 3)
    m = float(SK.sig_mmd(x, x, 3, unbiased=False))
    assert abs(m) < 1e-4


def test_mmd_separates_distributions(rng):
    def drifted(drift, n=16):
        steps = rng.normal(size=(n, 24, 2)) * 0.15 + drift
        return jnp.asarray(np.concatenate(
            [np.zeros((n, 1, 2)), np.cumsum(steps, axis=1)],
            axis=1).astype(np.float32))

    x = drifted(+0.1)
    near = float(SK.sig_mmd(x, drifted(+0.1), 3))    # same distribution
    far = float(SK.sig_mmd(x, drifted(-0.1), 3))     # mean-shifted paths
    assert far > 0
    assert far > 10 * abs(near)


def test_mmd_grad_agrees_jax_vs_pallas_interpret(rng):
    """Acceptance: jax.grad of the MMD loss agrees across backends."""
    x = make_path(rng, 5, 18, 3)
    y = make_path(rng, 6, 18, 3)

    def grad_of(backend):
        return jax.grad(lambda a: SK.sig_mmd(
            a, y, 3, gamma=(0.5, 1.0, 1.5), backend=backend))(x)

    g_jax = np.asarray(grad_of("jax"))
    g_pal = np.asarray(grad_of("pallas_interpret"))
    assert np.isfinite(g_jax).all() and np.abs(g_jax).max() > 0
    np.testing.assert_allclose(g_pal, g_jax,
                               atol=1e-5 * np.abs(g_jax).max())


def test_mmd_unbiased_needs_two(rng):
    x = make_path(rng, 1, 10, 2)
    y = make_path(rng, 4, 10, 2)
    with pytest.raises(ValueError):
        SK.sig_mmd(x, y, 2)


# ---------------------------------------------------------------------------
# low-rank features
# ---------------------------------------------------------------------------

def test_random_word_features_exact_when_complete(rng):
    x = make_path(rng, 5, 18, 3)
    y = make_path(rng, 4, 18, 3)
    D = sig_dim(3, 3)
    fm = SK.random_word_features(3, 3, n_features=D, gamma=(0.5, 1.0, 2.0))
    K = np.asarray(fm(x) @ fm(y).T)
    ref = np.asarray(SK.sig_gram(x, y, 3, gamma=(0.5, 1.0, 2.0)))
    np.testing.assert_allclose(K, ref, atol=1e-4 * np.abs(ref).max())


def test_random_word_features_approximates(rng):
    x = make_path(rng, 6, 20, 2)
    D = sig_dim(2, 5)
    ref = np.asarray(SK.sig_gram(x, None, 5))
    # average over seeds: the estimator is unbiased, so the mean converges
    Ks = [np.asarray((f := SK.random_word_features(2, 5, D // 2, seed=s))(x)
                     @ f(x).T) for s in range(8)]
    err = np.abs(np.mean(Ks, axis=0) - ref).max() / np.abs(ref).max()
    assert err < 0.35, err


def test_nystrom_exact_on_landmarks(rng):
    x = make_path(rng, 6, 20, 3)
    ny = SK.nystrom_features(x, 3, level_weights=(1.0, 0.5, 0.25))
    phi = ny(x)
    ref = np.asarray(SK.sig_gram(x, None, 3, level_weights=(1.0, 0.5, 0.25)))
    np.testing.assert_allclose(np.asarray(phi @ phi.T), ref,
                               atol=1e-4 * np.abs(ref).max())


def test_nystrom_generalises_off_landmarks(rng):
    lm = make_path(rng, 24, 20, 2)
    ny = SK.nystrom_features(lm, 3)
    x = make_path(rng, 5, 20, 2)
    y = make_path(rng, 4, 20, 2)
    approx = np.asarray(ny(x) @ ny(y).T)
    ref = np.asarray(SK.sig_gram(x, y, 3))
    assert np.abs(approx - ref).max() / np.abs(ref).max() < 0.3


# ---------------------------------------------------------------------------
# KRR + reference scoring
# ---------------------------------------------------------------------------

def test_krr_interpolates_training_data(rng):
    x = make_path(rng, 12, 20, 2)
    y = jnp.asarray(rng.normal(size=(12,)).astype(np.float32))
    model = SK.fit_sig_krr(x, y, 3, reg=1e-8)
    np.testing.assert_allclose(np.asarray(model.predict(x)), np.asarray(y),
                               atol=1e-2)


def test_krr_multi_output_and_words(rng):
    words = [(0,), (1,), (0, 1), (1, 0), (0, 0, 1)]
    x = make_path(rng, 10, 16, 2)
    y = jnp.asarray(rng.normal(size=(10, 3)).astype(np.float32))
    model = SK.fit_sig_krr(x, y, words=words, reg=1e-6)
    pred = model.predict(make_path(rng, 4, 16, 2))
    assert pred.shape == (4, 3)
    assert np.isfinite(np.asarray(pred)).all()


def test_reference_scores_self_retrieval(rng):
    refs = make_path(rng, 8, 24, 3)
    S = SK.signature_features(refs, 3)
    w = jnp.asarray(SK.word_weights(3, 3))
    scores = np.asarray(SK.reference_scores(S, S, w))
    # RKHS cosine: diagonal is 1 and is the row-max (self-retrieval)
    np.testing.assert_allclose(np.diag(scores), 1.0, atol=1e-4)
    assert (scores.argmax(axis=1) == np.arange(8)).all()


# ---------------------------------------------------------------------------
# serving: SigScoreEngine
# ---------------------------------------------------------------------------

def test_sig_score_engine_streams_match_references(rng):
    from repro.serve import SigScoreEngine
    refs = make_path(rng, 5, 16, 3)
    eng = SigScoreEngine(d=3, depth=3, batch=5, references=refs,
                         backend="jax")
    incs = tops.path_increments(refs)       # stream the references themselves
    scores = np.asarray(eng.push(incs))
    assert scores.shape == (5, 5)
    assert (np.asarray(eng.nearest()) == np.arange(5)).all()
    np.testing.assert_allclose(np.diag(scores), 1.0, atol=1e-4)


def test_sig_score_engine_chunked_equals_one_shot(rng):
    from repro.serve import SigScoreEngine
    refs = make_path(rng, 4, 12, 2)
    incs = jnp.asarray(rng.normal(size=(3, 10, 2)).astype(np.float32) * 0.3)
    one = SigScoreEngine(d=2, depth=3, batch=3, references=refs,
                         backend="jax")
    one_scores = np.asarray(one.push(incs))
    two = SigScoreEngine(d=2, depth=3, batch=3, references=refs,
                         backend="jax")
    two.push(incs[:, :4])
    two_scores = np.asarray(two.push(incs[:, 4:]))
    np.testing.assert_allclose(two_scores, one_scores, atol=1e-5)


def test_sig_score_engine_krr_predict_and_window(rng):
    from repro.serve import SigScoreEngine
    refs = make_path(rng, 6, 14, 2)
    targets = jnp.asarray(rng.normal(size=(6, 2)).astype(np.float32))
    eng = SigScoreEngine(d=2, depth=2, batch=3, references=refs,
                         targets=targets, window=8, backend="jax",
                         level_weights=(1.0, 0.5))
    for _ in range(3):
        eng.push(jnp.asarray(rng.normal(size=(3, 5, 2)).astype(np.float32)))
    assert eng.state.length == 8            # hopping window stays bounded
    pred = eng.predict()
    assert pred.shape == (3, 2) and np.isfinite(np.asarray(pred)).all()
    eng.reset()
    assert eng.state.length == 0


def test_sig_score_engine_requires_targets_for_predict(rng):
    from repro.serve import SigScoreEngine
    refs = make_path(rng, 3, 10, 2)
    eng = SigScoreEngine(d=2, depth=2, batch=2, references=refs,
                         backend="jax")
    with pytest.raises(ValueError):
        eng.predict()


# ---------------------------------------------------------------------------
# model head + trainer loss
# ---------------------------------------------------------------------------

def test_sig_kernel_head_forward_and_grads(rng):
    from repro.configs import get_config, reduce_config, with_sig_head
    from repro.models.sig_head import feature_dim, init_sig_head, sig_pool
    cfg = with_sig_head(reduce_config(get_config("qwen3-4b")), channels=3,
                        depth=3, kernel_landmarks=6, backend="jax")
    assert feature_dim(cfg.sig_head) == 6 + 3
    p = init_sig_head(jax.random.PRNGKey(0), cfg, 5)
    assert p["landmarks"].shape == (6, cfg.sig_head.landmark_steps + 1, 3)
    h = jnp.asarray(rng.normal(size=(2, 12, cfg.d_model)).astype(np.float32))
    out = sig_pool(p, h, cfg)
    assert out.shape == (2, 5)
    g = jax.grad(lambda pp: jnp.sum(sig_pool(pp, h, cfg) ** 2))(p)
    for key in ("proj", "out", "landmarks"):
        assert float(jnp.linalg.norm(g[key])) > 0, key


def test_sig_kernel_head_matches_manual_gram(rng):
    from repro.configs import get_config, reduce_config, with_sig_head
    from repro.models.sig_head import init_sig_head, sig_kernel_pool, \
        _learned_path
    from repro.core import signature
    cfg = with_sig_head(reduce_config(get_config("qwen3-4b")), channels=2,
                        depth=2, kernel_landmarks=4, kernel_normalize=False,
                        kernel_level_decay=0.5, backend="jax")
    p = init_sig_head(jax.random.PRNGKey(1), cfg, 3)
    h = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)).astype(np.float32))
    out = np.asarray(sig_kernel_pool(p, h, cfg))
    path = _learned_path(p, h, cfg.sig_head)
    S = signature(path, 2)
    S_l = signature(p["landmarks"].astype(jnp.float32), 2)
    w = jnp.asarray(SK.word_weights(2, 2, level_weights=(0.5, 0.25)))
    K = (S * w[None]) @ S_l.T
    feats = jnp.concatenate([K, path[:, -1] - path[:, 0]], axis=-1)
    ref = np.asarray(feats @ p["out"])
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_trainer_sig_mmd_loss_decreases(rng):
    import dataclasses
    import repro.models as M
    from repro.configs import get_config, reduce_config, with_sig_head
    from repro.optim import adamw
    from repro.train import make_train_step
    base = reduce_config(get_config("qwen3-4b"))
    cfg = dataclasses.replace(
        with_sig_head(base, channels=2, depth=2, backend="jax"),
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
        vocab_size=64, head_dim=16)
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    step = jax.jit(make_train_step(cfg, adamw(lr=3e-3), loss="sig_mmd"))
    batch = {"tokens": jnp.asarray(rng.integers(0, 64, size=(4, 12))),
             "paths": make_path(rng, 8, 11, 2)}
    opt_state = adamw(lr=3e-3).init(params)
    losses = []
    for _ in range(8):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_trainer_rejects_unknown_loss():
    from repro.configs import get_config, reduce_config, with_sig_head
    from repro.optim import adamw
    from repro.train import make_train_step
    cfg = reduce_config(get_config("qwen3-4b"))
    with pytest.raises(ValueError):
        make_train_step(cfg, adamw(lr=1e-3), loss="nope")
    with pytest.raises(ValueError):  # sig_mmd without a sig head config
        make_train_step(cfg, adamw(lr=1e-3), loss="sig_mmd")
    enc = with_sig_head(reduce_config(get_config("whisper-large-v3")),
                        channels=2, depth=2)
    with pytest.raises(ValueError):  # encdec has no backbone trajectory
        make_train_step(enc, adamw(lr=1e-3), loss="sig_mmd")


def test_eval_step_follows_trained_loss(rng):
    import dataclasses
    import repro.models as M
    from repro.configs import get_config, reduce_config, with_sig_head
    from repro.train import make_eval_step
    base = reduce_config(get_config("qwen3-4b"))
    cfg = dataclasses.replace(
        with_sig_head(base, channels=2, depth=2, backend="jax"),
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
        vocab_size=64, head_dim=16)
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    batch = {"tokens": jnp.asarray(rng.integers(0, 64, size=(4, 12))),
             "paths": make_path(rng, 8, 11, 2)}   # no labels: MMD-only batch
    metrics = make_eval_step(cfg, loss="sig_mmd")(params, batch)
    assert np.isfinite(float(metrics["sig_mmd"]))


def test_sig_stream_features_rejects_kernel_head(rng):
    from repro.configs import get_config, reduce_config, with_sig_head
    from repro.models.sig_head import init_sig_head, sig_stream_features
    cfg = with_sig_head(reduce_config(get_config("qwen3-4b")), channels=2,
                        depth=2, kernel_landmarks=4, backend="jax")
    p = init_sig_head(jax.random.PRNGKey(0), cfg, 3)
    h = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)).astype(np.float32))
    with pytest.raises(NotImplementedError):
        sig_stream_features(p, h, cfg)
