"""Mixed-precision (bf16 storage, fp32 accumulation) dispatch cells.

Precision is quantise-once-at-dispatch (a straight-through bf16 rounding of
the increments before any engine runs), so every backend × backward
combination must agree EXACTLY under ``precision="bf16_fp32"``; the forward
error against the fp32 oracle is the compounding of one bf16 rounding per
increment, bounded per level n by ~n·2^-8 relative (bf16 keeps 8 mantissa
bits).  The storage dtype halves the kernels' VMEM footprints.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.sig_trunc import choose_split, state_footprint
from repro.kernels.sig_words import tile_footprint

DEPTH = 6
B, M, d = 4, 40, 3


@pytest.fixture(autouse=True)
def _autotune_off(monkeypatch):
    monkeypatch.setenv("PATHSIG_AUTOTUNE", "off")


@pytest.fixture(scope="module")
def incs():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.standard_normal((B, M, d)).astype(np.float32)
                       * 0.2)


def _per_level_relerr(got, ref, d, depth):
    errs, off = [], 0
    for n in range(1, depth + 1):
        w = d ** n
        g, r = got[:, off:off + w], ref[:, off:off + w]
        errs.append(float(jnp.linalg.norm(g - r) /
                          jnp.maximum(jnp.linalg.norm(r), 1e-30)))
        off += w
    return errs


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
def test_bf16_per_level_error_bound(incs, backend):
    """Level-n relative error vs the fp32 oracle stays within n·2^-8 at
    depth <= 6 (the documented bound: n compounded bf16 roundings)."""
    ref = ops.signature(incs, DEPTH, backend="jax")
    got = ops.signature(incs, DEPTH, backend=backend, precision="bf16_fp32",
                        batch_tile=8)
    for n, err in enumerate(_per_level_relerr(got, ref, d, DEPTH), start=1):
        assert err <= n * 2.0 ** -8, (n, err)


def test_bf16_engines_agree_exactly(incs):
    """Rounding happens ONCE at dispatch, so engines agree to fp32 noise."""
    a = ops.signature(incs, 4, backend="jax", precision="bf16_fp32")
    b = ops.signature(incs, 4, backend="pallas_interpret",
                      precision="bf16_fp32", batch_tile=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-6)


@pytest.mark.parametrize("bwd", ["inverse", "checkpoint", "autodiff"])
def test_bf16_grads_finite_and_backends_agree(incs, bwd):
    co = jnp.asarray(np.random.default_rng(1).standard_normal(
        (B, sum(d ** n for n in range(1, 4)))).astype(np.float32))

    def loss(backend):
        return jax.grad(lambda x: jnp.vdot(ops.signature(
            x, 3, backend=backend, backward=bwd, precision="bf16_fp32",
            batch_tile=8), co))(incs)

    gj, gp = loss("jax"), loss("pallas_interpret")
    assert np.isfinite(np.asarray(gj)).all()
    np.testing.assert_allclose(np.asarray(gj), np.asarray(gp), atol=3e-5)


def test_bf16_projected_and_gram(incs):
    from repro.core.words import all_words
    words = tuple(all_words(d, 3))
    ref = ops.projected(incs, words, backend="jax")
    got = ops.projected(incs, words, backend="pallas_interpret",
                        precision="bf16_fp32", batch_tile=8)
    # projections are signature coordinates: same per-level (relative) bound
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert rel < 3 * 2.0 ** -8
    S = ops.signature(incs, 3, backend="jax")
    w = jnp.ones(S.shape[1], jnp.float32)
    g32 = ops.gram(S, S, w, backend="pallas_interpret")
    g16 = ops.gram(S, S, w, backend="pallas_interpret",
                   precision="bf16_fp32")
    rel = float(jnp.max(jnp.abs(g16 - g32)) / jnp.max(jnp.abs(g32)))
    assert rel < 2.0 ** -7


def test_bf16_streamed_per_level_error_bound(incs):
    """Streamed emission under bf16_fp32: the kernels' emission buffers
    store bf16 (fp32 scratch accumulators), adding at most ONE more
    rounding on top of the per-increment storage rounding — level-n error
    of every emitted frame vs the fp32 streamed oracle stays within
    (n+1)·2^-8."""
    ref = ops.signature(incs, DEPTH, backend="jax", stream=True,
                        stream_stride=5)
    for backend in ("jax", "pallas_interpret"):
        got = ops.signature(incs, DEPTH, backend=backend, stream=True,
                            stream_stride=5, precision="bf16_fp32",
                            batch_tile=8)
        assert got.dtype == ref.dtype  # storage dtype never leaks out
        errs, off = [], 0
        for n in range(1, DEPTH + 1):
            w = d ** n
            g, r = got[..., off:off + w], ref[..., off:off + w]
            err = float(jnp.linalg.norm(g - r) /
                        jnp.maximum(jnp.linalg.norm(r), 1e-30))
            assert err <= (n + 1) * 2.0 ** -8, (backend, n, err)
            off += w


def test_bf16_streamed_engines_agree_exactly(incs):
    """The dispatch-level straight-through rounding of the emitted frames
    is idempotent and shared, so both engines land on the same bf16 grid
    points — streamed outputs agree to the bit."""
    a = ops.signature(incs, 4, backend="jax", stream=True, stream_stride=5,
                      precision="bf16_fp32")
    b = ops.signature(incs, 4, backend="pallas_interpret", stream=True,
                      stream_stride=5, precision="bf16_fp32", batch_tile=8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    from repro.core.words import all_words
    words = tuple(all_words(d, 3))
    pa = ops.projected(incs, words, backend="jax", stream=True,
                       stream_stride=5, precision="bf16_fp32")
    pb = ops.projected(incs, words, backend="pallas_interpret", stream=True,
                       stream_stride=5, precision="bf16_fp32", batch_tile=8)
    np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


def test_bf16_streamed_terminal_matches_nonstreamed(incs):
    """The terminal emitted frame is the non-streamed bf16 result plus at
    most one emission rounding: within one bf16 ulp relative."""
    s = ops.signature(incs, DEPTH, backend="jax", stream=True,
                      stream_stride=5, precision="bf16_fp32")
    ns = ops.signature(incs, DEPTH, backend="jax", precision="bf16_fp32")
    rel = float(jnp.max(jnp.abs(s[:, -1] - ns)) / jnp.max(jnp.abs(ns)))
    assert rel <= 2.0 ** -8, rel


@pytest.mark.parametrize("bwd", ["inverse", "autodiff"])
def test_bf16_streamed_grads_finite_and_backends_agree(incs, bwd):
    D3 = sum(d ** n for n in range(1, 4))
    co = jnp.asarray(np.random.default_rng(1).standard_normal(
        (B, 8, D3)).astype(np.float32))

    def g(backend):
        return jax.grad(lambda x: jnp.vdot(ops.signature(
            x, 3, backend=backend, backward=bwd, stream=True,
            stream_stride=5, precision="bf16_fp32", batch_tile=8), co))(incs)

    gj, gp = g("jax"), g("pallas_interpret")
    assert np.isfinite(np.asarray(gj)).all()
    scale = float(jnp.max(jnp.abs(gj)))
    assert float(jnp.max(jnp.abs(gj - gp))) <= 2.0 ** -7 * scale


def test_bf16_halves_state_footprint():
    """Satellite: the bytes-per-element literals are dtype-parameterised —
    bf16 storage halves both kernels' VMEM footprints exactly."""
    assert state_footprint(4, 5, 2, 128, itemsize=2) * 2 == \
        state_footprint(4, 5, 2, 128, itemsize=4)
    assert tile_footprint(64, 4, 3, 128, itemsize=2) * 2 == \
        tile_footprint(64, 4, 3, 128, itemsize=4)


def test_choose_split_sees_dtype():
    """Halving the element size can only loosen the split (more state fits
    in the same VMEM budget), and does so strictly on a budget that fp32
    just overflows."""
    d_, depth_, bt = 4, 6, 128
    s32 = choose_split(d_, depth_, bt, itemsize=4)
    s16 = choose_split(d_, depth_, bt, itemsize=2)
    assert s16 <= s32
    # a budget exactly at the bf16 footprint of split 0 separates the two
    budget = state_footprint(d_, depth_, 0, bt, itemsize=2)
    assert choose_split(d_, depth_, bt, vmem_budget=budget, itemsize=2) == 0
    assert choose_split(d_, depth_, bt, vmem_budget=budget, itemsize=4) > 0


def test_canon_precision_aliases():
    from repro.core.signature import canon_precision
    assert canon_precision("bf16") == "bf16_fp32"
    assert canon_precision("fp32") == "fp32"
    with pytest.raises(ValueError):
        canon_precision("fp64")
