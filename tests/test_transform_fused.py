"""Fused path transforms vs the materialising oracle (kernels/ops dispatch).

Every cell checks the fused route — raw increments + ``transform=`` into the
sweep — against ``apply_transform`` followed by the plain engine, on outputs
AND gradients (the §4.2 reverse sweeps pull the cotangent back through
``fused_adjoint``), across backend × backward × stream × ragged.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.transforms import (apply_transform, as_transform,
                                   transform_dim, transform_lengths)
from repro.core.words import all_words
from repro.kernels import ops

B, M, d, DEPTH = 5, 11, 2, 3
LENGTHS = np.asarray([11, 7, 1, 0, 5])


@pytest.fixture(autouse=True)
def _autotune_off(monkeypatch):
    monkeypatch.setenv("PATHSIG_AUTOTUNE", "off")


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    path = jnp.asarray(rng.standard_normal((B, M + 1, d)).astype(np.float32)
                       * 0.3)
    return path, jnp.diff(path, axis=1), path[:, 0]


def _aug(path, spec, lens):
    aug = apply_transform(path, spec, lengths=lens)
    return aug[0] if isinstance(aug, tuple) else aug


def _oracle_incs(path, spec, lens):
    return jnp.diff(_aug(path, spec, None if lens is None else lens), axis=1)


TRANSFORMS = ["time_augment", "lead_lag", "basepoint",
              "time_augment+lead_lag", "basepoint+lead_lag+time_augment"]


@pytest.mark.parametrize("ragged", [False, True], ids=["full", "ragged"])
@pytest.mark.parametrize("backend", ["pallas_interpret", "jax"])
@pytest.mark.parametrize("tname", TRANSFORMS)
def test_fused_signature_matches_materialised(data, tname, backend, ragged):
    path, incs, x0 = data
    spec = as_transform(tname)
    lens = jnp.asarray(LENGTHS) if ragged else None
    al = None if lens is None else transform_lengths(spec, lens)
    ref = ops.signature(_oracle_incs(path, spec, lens), DEPTH, backend="jax",
                        lengths=al)
    got = ops.signature(incs, DEPTH, backend=backend, transform=tname,
                        x0=x0, lengths=lens, batch_tile=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-6)


@pytest.mark.parametrize("bwd", ["inverse", "autodiff", "checkpoint"])
@pytest.mark.parametrize("backend", ["pallas_interpret", "jax"])
def test_fused_signature_grads(data, backend, bwd):
    path, incs, x0 = data
    tname = "basepoint+lead_lag+time_augment"
    spec = as_transform(tname)
    lens = jnp.asarray(LENGTHS)
    al = transform_lengths(spec, lens)
    from repro.core import sig_dim
    co = jnp.asarray(np.random.default_rng(1).standard_normal(
        (B, sig_dim(transform_dim(spec, d), DEPTH))).astype(np.float32))

    def fused(x, x0):
        return jnp.vdot(ops.signature(x, DEPTH, backend=backend, backward=bwd,
                                      transform=tname, x0=x0, lengths=lens,
                                      batch_tile=8), co)

    def oracle(x, x0):
        p = jnp.concatenate([x0[:, None], x0[:, None] + jnp.cumsum(x, 1)], 1)
        return jnp.vdot(ops.signature(_oracle_incs(p, spec, lens), DEPTH,
                                      backend="jax", lengths=al), co)

    gi, gx = jax.grad(fused, argnums=(0, 1))(incs, x0)
    ri, rx = jax.grad(oracle, argnums=(0, 1))(incs, x0)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(ri), atol=3e-5)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), atol=3e-5)


@pytest.mark.parametrize("ragged", [False, True], ids=["full", "ragged"])
@pytest.mark.parametrize("stride", [1, 3])
@pytest.mark.parametrize("backend", ["pallas_interpret", "jax"])
def test_fused_stream_matches_materialised(data, backend, stride, ragged):
    path, incs, x0 = data
    tname = "time_augment+lead_lag"
    spec = as_transform(tname)
    lens = jnp.asarray(LENGTHS) if ragged else None
    al = None if lens is None else transform_lengths(spec, lens)
    ref = ops.signature(_oracle_incs(path, spec, lens), DEPTH, backend="jax",
                        stream=True, stream_stride=stride, lengths=al)
    got = ops.signature(incs, DEPTH, backend=backend, stream=True,
                        stream_stride=stride, transform=tname, x0=x0,
                        lengths=lens, batch_tile=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-6)
    co = jnp.asarray(np.random.default_rng(2).standard_normal(
        ref.shape).astype(np.float32))
    gi = jax.grad(lambda x: jnp.vdot(ops.signature(
        x, DEPTH, backend=backend, stream=True, stream_stride=stride,
        transform=tname, x0=x0, lengths=lens, batch_tile=8), co))(incs)
    ri = jax.grad(lambda x: jnp.vdot(ops.signature(
        _oracle_incs(jnp.concatenate(
            [x0[:, None], x0[:, None] + jnp.cumsum(x, 1)], 1), spec, lens),
        DEPTH, backend="jax", stream=True, stream_stride=stride,
        lengths=al), co))(incs)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(ri), atol=3e-5)


@pytest.mark.parametrize("ragged", [False, True], ids=["full", "ragged"])
@pytest.mark.parametrize("backend", ["pallas_interpret", "jax", "hybrid"])
def test_fused_projected_matches_materialised(data, backend, ragged):
    path, incs, x0 = data
    spec = as_transform("time_augment+lead_lag")
    words = tuple(all_words(transform_dim(spec, d), 3))[:40]
    lens = jnp.asarray(LENGTHS) if ragged else None
    al = None if lens is None else transform_lengths(spec, lens)
    ref = ops.projected(_oracle_incs(path, spec, lens), words, backend="jax",
                        lengths=al)
    got = ops.projected(incs, words, backend=backend, transform=spec,
                        lengths=lens, batch_tile=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-6)
    co = jnp.asarray(np.random.default_rng(3).standard_normal(
        ref.shape).astype(np.float32))
    gi = jax.grad(lambda x: jnp.vdot(ops.projected(
        x, words, backend=backend, transform=spec, lengths=lens,
        batch_tile=8), co))(incs)
    ri = jax.grad(lambda x: jnp.vdot(ops.projected(
        _oracle_incs(jnp.concatenate(
            [path[:, :1], path[:, :1] + jnp.cumsum(x, 1)], 1), spec, lens),
        words, backend="jax", lengths=al), co))(incs)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(ri), atol=3e-5)


@pytest.mark.parametrize("backend", ["pallas_interpret", "jax"])
def test_fused_projected_stream_and_forward_only(data, backend):
    path, incs, x0 = data
    spec = as_transform("time_augment+lead_lag")
    words = tuple(all_words(transform_dim(spec, d), 3))[:40]
    lens = jnp.asarray(LENGTHS)
    al = transform_lengths(spec, lens)
    e = _oracle_incs(path, spec, lens)
    ref = ops.projected(e, words, backend="jax", stream=True,
                        stream_stride=2, lengths=al)
    got = ops.projected(incs, words, backend=backend, stream=True,
                        stream_stride=2, transform=spec, lengths=lens,
                        batch_tile=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-6)
    reff = ops.projected_forward_only(e, words, backend="jax", lengths=al)
    gotf = ops.projected_forward_only(incs, words, backend=backend,
                                      transform=spec, lengths=lens,
                                      batch_tile=8)
    np.testing.assert_allclose(np.asarray(gotf), np.asarray(reff), atol=3e-6)


def test_basepoint_without_x0_raises(data):
    _, incs, _ = data
    with pytest.raises(ValueError, match="x0"):
        ops.signature(incs, DEPTH, backend="pallas_interpret",
                      transform="basepoint")


def test_projected_plan_over_raw_alphabet_raises(data):
    _, incs, _ = data
    from repro.core.words import make_plan
    plan = make_plan(tuple(all_words(d, 2)), d)  # raw alphabet prebuilt
    with pytest.raises(ValueError, match="augmented alphabet"):
        ops.projected(incs, plan, backend="jax",
                      transform="time_augment+lead_lag")


def test_core_signature_passes_x0_automatically(data):
    path, _, _ = data
    spec = as_transform("basepoint+time_augment")
    from repro.core.signature import signature as path_signature
    got = path_signature(path, DEPTH, transform="basepoint+time_augment",
                         backend="pallas_interpret")
    ref = ops.signature(_oracle_incs(path, spec, None), DEPTH, backend="jax")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-6)
