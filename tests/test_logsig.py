"""Log-signature tests: Lyndon basis, dense vs projected route (paper §3.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core as C
from repro.core.logsignature import (_projected_tables, logsignature,
                                     logsignature_projected)
from repro.core import tensor_ops as tops
from tests.conftest import make_path


def _necklace_dim(d, N):
    """dim g_{<=N} = sum_n (1/n) sum_{k|n} mu(k) d^(n/k)."""
    def mu(n):
        out, m, p = 1, n, 2
        while p * p <= m:
            if m % p == 0:
                m //= p
                if m % p == 0:
                    return 0
                out = -out
            p += 1
        return -out if m > 1 else out

    total = 0
    for n in range(1, N + 1):
        s = sum(mu(k) * d ** (n // k) for k in range(1, n + 1) if n % k == 0)
        total += s // n
    return total


@pytest.mark.parametrize("d,N", [(2, 3), (2, 5), (3, 3), (4, 3), (5, 2)])
def test_lyndon_count_matches_necklace_formula(d, N):
    assert C.logsig_dim(d, N) == _necklace_dim(d, N)


@pytest.mark.parametrize("d,N", [(2, 4), (3, 3), (4, 3), (2, 6), (5, 2),
                                 (3, 5)])
def test_projected_matches_dense(rng, d, N):
    path = make_path(rng, 3, 13, d)
    np.testing.assert_allclose(logsignature_projected(path, N),
                               logsignature(path, N), rtol=2e-4, atol=1e-5)


def test_projected_skips_top_level_coefficients():
    """The projection trick computes |W_{<=N-1}| + |Lyndon_N| coefficients,
    strictly fewer than |W_{<=N}| (the whole point of §3.3)."""
    d, N = 4, 5
    plan = _projected_tables(d, N)[0]
    n_lyndon_top = sum(1 for w in C.lyndon_words(d, N) if len(w) == N)
    assert len(plan.words) == C.sig_dim(d, N - 1) + n_lyndon_top
    assert plan.closure_size < C.sig_dim(d, N)
    # savings are dominated by the top level: d^N - |Lyndon_N| words skipped
    assert C.sig_dim(d, N) - plan.closure_size >= (d ** N - n_lyndon_top) // 2


def test_single_segment_logsig_is_increment(rng):
    """log(exp(dx)) = dx: only level-1 coordinates survive."""
    d, N = 3, 4
    dx = rng.normal(size=(1, d)).astype(np.float32) * 0.4
    path = np.stack([np.zeros((1, d), np.float32), dx], axis=1)
    for fn in (logsignature, logsignature_projected):
        ls = np.asarray(fn(jnp.asarray(path), N))
        np.testing.assert_allclose(ls[:, :d], dx, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(ls[:, d:], 0.0, atol=1e-5)


def test_reparametrisation_invariance(rng):
    path = make_path(rng, 2, 9, 3)
    path2 = np.concatenate([path[:, :4], path[:, 3:4], path[:, 4:]], axis=1)
    np.testing.assert_allclose(logsignature_projected(path2, 3),
                               logsignature_projected(path, 3),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("route", ["dense", "projected"])
def test_gradients_flow(rng, route):
    fn = logsignature if route == "dense" else logsignature_projected
    path = jnp.asarray(make_path(rng, 2, 8, 3))
    g = jax.grad(lambda p: jnp.sum(fn(p, 3) ** 2))(path)
    assert g.shape == path.shape
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.max(jnp.abs(g))) > 0


def test_grad_routes_agree(rng):
    path = jnp.asarray(make_path(rng, 2, 8, 3))
    gd = jax.grad(lambda p: jnp.sum(logsignature(p, 3) ** 2))(path)
    gp = jax.grad(lambda p: jnp.sum(logsignature_projected(p, 3) ** 2))(path)
    np.testing.assert_allclose(gd, gp, rtol=2e-3, atol=1e-5)


@given(st.integers(2, 3), st.integers(2, 4), st.integers(3, 10))
@settings(max_examples=10, deadline=None)
def test_logsig_lives_in_lie_algebra_level2(d, N, M):
    """Level-2 of log(S) is antisymmetric (primitive elements at level 2 are
    spanned by commutators [e_i, e_j])."""
    rng = np.random.default_rng(d * 100 + N * 10 + M)
    path = make_path(rng, 2, M, d)
    flat = C.signature(path, max(N, 2))
    logs = tops.tensor_log(tops.flat_to_levels(jnp.asarray(flat), d,
                                               max(N, 2)))
    lvl2 = np.asarray(logs[1]).reshape(-1, d, d)
    np.testing.assert_allclose(lvl2 + np.swapaxes(lvl2, 1, 2), 0.0,
                               atol=1e-4)


def test_basepoint_flag(rng):
    path = jnp.asarray(make_path(rng, 2, 7, 3))
    with_bp = logsignature(path, 3, basepoint=True)
    manual = jnp.concatenate([jnp.zeros_like(path[:, :1]), path], axis=1)
    np.testing.assert_allclose(with_bp, logsignature(manual, 3),
                               rtol=1e-5, atol=1e-6)


def test_hybrid_engine_matches_word_table_engine(rng):
    """The hybrid dense+top engine equals the generic word-table engine on
    the §3.3 plan (all words <N ++ Lyndon_N), values and gradients."""
    from repro.core.hybrid import hybrid_low_plus_top
    from repro.core.logsignature import _projected_tables
    from repro.core.projection import projected_signature_from_increments
    from repro.core import tensor_ops as tops

    d, N = 3, 4
    path = jnp.asarray(make_path(rng, 2, 9, d))
    incs = tops.path_increments(path)
    plan = _projected_tables(d, N)[0]
    top = [w for w in C.lyndon_words(d, N) if len(w) == N]
    a = hybrid_low_plus_top(incs, top, N)
    b = projected_signature_from_increments(incs, plan)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)

    ga = jax.grad(lambda x: jnp.sum(hybrid_low_plus_top(x, top, N) ** 2))(incs)
    gb = jax.grad(lambda x: jnp.sum(
        projected_signature_from_increments(x, plan) ** 2))(incs)
    np.testing.assert_allclose(ga, gb, rtol=1e-3, atol=1e-5)
    # inverse-reconstruction VJP == autodiff-through-scan VJP
    gc = jax.grad(lambda x: jnp.sum(
        hybrid_low_plus_top(x, top, N, backward="autodiff") ** 2))(incs)
    np.testing.assert_allclose(ga, gc, rtol=1e-3, atol=1e-5)
