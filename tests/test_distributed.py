"""Multi-device integration: EXECUTE (not just compile) sharded train and
decode steps on 8 placeholder CPU devices in a subprocess (the main test
process must keep seeing 1 device — XLA locks the count at first init)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    import repro.models as M
    from repro.configs import get_config, reduce_config
    from repro.distributed.sharding import batch_specs, cache_specs, \\
        opt_state_specs, param_specs
    from repro.distributed.ctx import sharding_ctx
    from repro.optim import adamw
    from repro.serve import make_serve_step
    from repro.train import make_train_step

    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = reduce_config(get_config("{arch}"))
    rules = {{}}

    with sharding_ctx(mesh, rules):
        params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        p_specs = param_specs(params, mesh, rules)
        params = jax.device_put(params, p_specs)
        opt = adamw(lr=1e-3)
        o_specs = opt_state_specs(jax.eval_shape(opt.init, params),
                                  p_specs, mesh)
        opt_state = jax.jit(opt.init, out_shardings=o_specs)(params)
        batch = {{"tokens": jnp.ones((4, 16), jnp.int32),
                 "labels": jnp.ones((4, 16), jnp.int32)}}
        b_specs = batch_specs(batch, mesh, rules)
        batch = jax.device_put(batch, b_specs)
        step = jax.jit(make_train_step(cfg, opt),
                       in_shardings=(p_specs, o_specs, b_specs),
                       out_shardings=(p_specs, o_specs, None))
        l0 = None
        for _ in range(3):
            params, opt_state, m = step(params, opt_state, batch)
            loss = float(m["loss"])
            assert np.isfinite(loss), loss
            l0 = loss if l0 is None else l0
        assert loss < l0 + 1e-3, (l0, loss)   # training on repeated batch

        # sharded decode: one token against a cache
        cache = M.init_cache(cfg, 4, 32, jnp.float32)
        c_specs = cache_specs(cache, mesh, rules)
        cache = jax.device_put(cache, c_specs)
        serve = jax.jit(make_serve_step(cfg),
                        in_shardings=(p_specs, c_specs, None, None),
                        out_shardings=(None, c_specs))
        tok = jnp.ones((4, 1), jnp.int32)
        for _ in range(3):
            tok, cache = serve(params, cache, tok, jax.random.PRNGKey(0))
        assert tok.shape == (4, 1) and int(tok.max()) < cfg.vocab_size
    print("DISTOK {arch}")
""")


@pytest.mark.parametrize("arch", ["qwen3-4b", "rwkv6-1.6b", "zamba2-7b"])
def test_sharded_train_and_decode_execute_on_8_devices(arch):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT.format(arch=arch)],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    assert f"DISTOK {arch}" in r.stdout
