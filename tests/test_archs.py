"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, shape + finiteness asserts, and prefill ==
incremental-decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.models import encdec, transformer as T
from repro.configs import ARCH_IDS, get_config, reduce_config, with_sig_head

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    batch = {"labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.full((B, 16, cfg.d_model), 0.01, jnp.float32)
        batch["tokens"] = jnp.ones((B, S), jnp.int32)
    elif cfg.rope_type == "mrope":
        batch["embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.02
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S))
    else:
        batch["tokens"] = jnp.ones((B, S), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = reduce_config(get_config(arch))
    params = M.init_params(KEY, cfg)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    # gradient reaches the embedding / frontend
    leaf = grads["embed"] if "embed" in grads else jax.tree.leaves(grads)[0]
    assert float(jnp.max(jnp.abs(leaf))) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_equals_decode(arch):
    cfg = reduce_config(get_config(arch))
    params = M.init_params(KEY, cfg)
    B, S = 2, 6
    if cfg.family == "encdec":
        F = 8
        frames = jax.random.normal(KEY, (B, F, cfg.d_model)) * 0.1
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
        enc = encdec.encode(params, cfg, frames, remat="none")
        hid = encdec.decode_train(params, cfg, enc, toks, remat="none")
        full = jnp.einsum("bsd,vd->bsv", hid, params["embed"])
        cache = encdec.prefill_cross(params, cfg, enc,
                                     encdec.init_cache(cfg, B, F, jnp.float32))
        dec = []
        for j in range(S):
            lg, cache = M.decode_step(params, cfg, toks[:, j:j + 1], cache)
            dec.append(lg)
    elif cfg.rope_type == "mrope":
        emb = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.1
        pos = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
        hidden, _ = T.backbone(params, cfg, embeds=emb, positions=pos,
                               remat="none")
        full = T.logits_fn(params, cfg, hidden)
        cache = M.init_cache(cfg, B, S, jnp.float32)
        dec = []
        for j in range(S):
            lg, cache = M.decode_step(params, cfg, None, cache,
                                      embeds=emb[:, j:j + 1],
                                      positions=pos[:, :, j:j + 1])
            dec.append(lg)
    else:
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
        hidden, _ = T.backbone(params, cfg, tokens=toks, remat="none")
        full = T.logits_fn(params, cfg, hidden)
        cache = M.init_cache(cfg, B, S, jnp.float32)
        dec = []
        for j in range(S):
            lg, cache = M.decode_step(params, cfg, toks[:, j:j + 1], cache)
            dec.append(lg)
    err = float(jnp.max(jnp.abs(jnp.concatenate(dec, 1) - full)))
    assert err < 2e-3, (arch, err)


@pytest.mark.parametrize("remat", ["none", "full", "dots"])
def test_remat_modes_equal_loss(remat):
    cfg = reduce_config(get_config("qwen3-4b"))
    params = M.init_params(KEY, cfg)
    batch = _batch(cfg)
    loss, _ = M.loss_fn(params, cfg, batch, remat=remat)
    loss0, _ = M.loss_fn(params, cfg, batch, remat="none")
    assert abs(float(loss) - float(loss0)) < 1e-5


def test_moe_capacity_drops_at_scale():
    """Capacity dispatch must kick in (and drop) for large token counts."""
    import repro.models.layers as L
    cfg = dataclasses.replace(reduce_config(get_config("phi3.5-moe-42b-a6.6b")),
                              capacity_factor=0.5)
    p = L.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (4, 32, cfg.d_model)) * 0.1  # T=128 > 4E
    out, aux = L.moe(p, x, cfg)
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0


def test_sig_head_pooling():
    """The paper's technique as a model component on hidden trajectories."""
    from repro.models.sig_head import init_sig_head, sig_pool
    cfg = with_sig_head(reduce_config(get_config("qwen3-4b")),
                        channels=4, depth=3)
    params = M.init_params(KEY, cfg)
    hp = init_sig_head(KEY, cfg, n_out=5)
    batch = _batch(cfg)
    hidden, _ = T.backbone(params, cfg, tokens=batch["tokens"])

    def loss(hp_):
        return jnp.sum(sig_pool(hp_, hidden, cfg) ** 2)

    g = jax.grad(loss)(hp)
    assert np.isfinite(float(loss(hp)))
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(g))


def test_param_count_sanity_full_configs():
    """Analytic parameter counts should be within the ballpark the arch names
    claim (dense: ±40%; these are sheet configs, not checkpoints)."""
    expect = {"llama3-405b": 405e9, "qwen1.5-32b": 32e9,
              "command-r-35b": 35e9, "qwen3-4b": 4e9,
              "phi3.5-moe-42b-a6.6b": 42e9, "deepseek-v2-lite-16b": 16e9,
              "zamba2-7b": 7e9, "rwkv6-1.6b": 1.6e9,
              "qwen2-vl-2b": 2e9, "whisper-large-v3": 1.5e9}
    for arch, want in expect.items():
        got = get_config(arch).param_count()
        assert 0.5 * want < got < 1.8 * want, (arch, got, want)


def test_active_params_moe():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    assert cfg.active_param_count() < 0.3 * cfg.param_count()
