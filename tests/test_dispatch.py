"""Engine-dispatch tests: every backend is differentiable (the grad-through-
kernels regression), cross-engine golden agreement vs the exp/Chen oracle,
and dtype/shape edge cases (B=1, M=1, float64)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core import tensor_ops as tops
from repro.core.words import make_plan
from repro.kernels import ops

BACKENDS = ["jax", "pallas", "pallas_interpret", "auto"]
WORDS = [(0,), (2, 1), (1, 1, 0), (0, 0, 1)]


def _incs(seed, B, M, d, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(B, M, d)).astype(dtype) * 0.3)


def _plan():
    return make_plan(WORDS, 3)


# ---------------------------------------------------------------------------
# regression: jax.grad succeeds through EVERY backend string (the docstring
# used to promise this while the Pallas path raised AssertionError)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_grad_through_signature_every_backend(backend):
    x = _incs(0, 2, 7, 3)
    g = jax.grad(lambda z: ops.signature(z, 3, backend=backend,
                                         batch_tile=8).sum())(x)
    assert g.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.max(jnp.abs(g))) > 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_grad_through_projected_every_backend(backend):
    x = _incs(1, 2, 7, 3)
    g = jax.grad(lambda z: ops.projected(z, _plan(), backend=backend,
                                         batch_tile=8).sum())(x)
    assert g.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(g)))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("backward", ["inverse", "checkpoint", "autodiff"])
def test_grad_every_backend_backward_combination(backend, backward):
    x = _incs(2, 2, 9, 2)
    g = jax.grad(lambda z: ops.signature(z, 3, backend=backend,
                                         backward=backward,
                                         batch_tile=8).sum())(x)
    assert g.shape == x.shape and bool(jnp.all(jnp.isfinite(g)))


def test_unknown_backend_and_backward_raise():
    x = _incs(3, 1, 4, 2)
    with pytest.raises(ValueError, match="unknown backend"):
        ops.signature(x, 2, backend="cuda")
    with pytest.raises(ValueError, match="unknown backward"):
        ops.signature(x, 2, backend="pallas_interpret", backward="nope")


@pytest.mark.parametrize("backend", BACKENDS)
def test_grad_through_streamed_signature_every_backend(backend):
    x = _incs(4, 2, 7, 3)
    g = jax.grad(lambda z: ops.signature(z, 3, backend=backend, batch_tile=8,
                                         stream=True).sum())(x)
    assert g.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.max(jnp.abs(g))) > 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_grad_through_streamed_projected_every_backend(backend):
    x = _incs(5, 2, 7, 3)
    g = jax.grad(lambda z: ops.projected(z, _plan(), backend=backend,
                                         batch_tile=8, stream=True).sum())(x)
    assert g.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(g)))


def test_stream_through_core_entry_point_routes_to_pallas():
    """stream=True + pallas used to silently drop to the JAX scan; it now
    routes through dispatch (and unsupported cells raise, see test_stream)."""
    from repro.core.signature import signature_from_increments
    x = _incs(6, 2, 6, 2)
    a = signature_from_increments(x, 3, stream=True, backend="pallas_interpret")
    b = signature_from_increments(x, 3, stream=True, backend="jax")
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_plan_caches_are_content_keyed():
    """Rebuilding an identical WordPlan must hit the same kernel caches
    instead of recompiling (WordPlan hashes by identity, eq=False)."""
    x = _incs(7, 2, 5, 3)
    words = [(0,), (1, 2)]
    p1, p2 = make_plan(words, 3), make_plan(words, 3)
    assert p1 is not p2
    ops.projected(x, p1, backend="pallas_interpret", batch_tile=8)
    before = ops._pallas_proj_inverse.cache_info()
    ops.projected(x, p2, backend="pallas_interpret", batch_tile=8)
    after = ops._pallas_proj_inverse.cache_info()
    assert after.currsize == before.currsize
    assert after.hits == before.hits + 1
    # the interned WordPlan is shared, so downstream jit caches are too
    assert ops._plan_for_words(tuple(words), 3) is \
        ops._plan_for_words(tuple(words), 3)


# ---------------------------------------------------------------------------
# cross-engine golden: pallas_interpret vs jax vs the exp/Chen oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,M,d,N", [(3, 12, 3, 4), (1, 9, 2, 3), (2, 1, 3, 3),
                                     (1, 1, 2, 2)])
def test_truncated_cross_engine_values(B, M, d, N):
    x = _incs(B * M + d, B, M, d)
    oracle = tops.signature_exp_chen(x, N)
    a = ops.signature(x, N, backend="jax")
    b = ops.signature(x, N, backend="pallas_interpret", batch_tile=8)
    np.testing.assert_allclose(a, oracle, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(b, oracle, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("B,M", [(3, 12), (1, 9), (2, 1), (1, 1)])
def test_truncated_cross_engine_gradients(B, M):
    x = _incs(10 + B * M, B, M, 3)

    def loss(backend, backward="inverse"):
        return lambda z: jnp.sum(jnp.tanh(
            ops.signature(z, 4, backend=backend, backward=backward,
                          batch_tile=8)))

    g_jax = jax.grad(loss("jax"))(x)
    g_pal = jax.grad(loss("pallas_interpret"))(x)
    g_cp = jax.grad(loss("pallas_interpret", "checkpoint"))(x)
    np.testing.assert_allclose(g_pal, g_jax, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(g_cp, g_jax, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("B,M", [(2, 11), (1, 1)])
def test_projected_cross_engine_values_and_gradients(B, M):
    d = 3
    x = _incs(20 + B * M, B, M, d)
    plan = _plan()
    # values: both engines vs the dense oracle read at the requested words
    dense = tops.signature_exp_chen(x, 3)
    idx = [C.flat_index(w, d) for w in WORDS]
    a = ops.projected(x, plan, backend="jax")
    b = ops.projected(x, plan, backend="pallas_interpret", batch_tile=8)
    np.testing.assert_allclose(a, dense[:, idx], rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(b, dense[:, idx], rtol=1e-4, atol=1e-6)
    # gradients agree across engines
    g_jax = jax.grad(lambda z: jnp.sum(jnp.sin(
        ops.projected(z, plan, backend="jax"))))(x)
    g_pal = jax.grad(lambda z: jnp.sum(jnp.sin(
        ops.projected(z, plan, backend="pallas_interpret",
                      batch_tile=8))))(x)
    np.testing.assert_allclose(g_pal, g_jax, rtol=1e-4, atol=1e-6)


def test_projected_checkpoint_backward_matches_inverse():
    x = _incs(30, 2, 13, 3)
    plan = _plan()
    g_inv = jax.grad(lambda z: jnp.sum(
        ops.projected(z, plan, backend="jax", backward="inverse") ** 2))(x)
    g_cp = jax.grad(lambda z: jnp.sum(
        ops.projected(z, plan, backend="jax", backward="checkpoint") ** 2))(x)
    np.testing.assert_allclose(g_cp, g_inv, rtol=1e-4, atol=1e-6)


def test_windowed_cross_engine_values_and_gradients(rng):
    from tests.conftest import make_path
    path = jnp.asarray(make_path(rng, 2, 16, 3))
    windows = np.asarray([[0, 8], [4, 16], [7, 8]], np.int32)
    a = C.windowed_signature(path, windows, 3, backend="jax")
    b = C.windowed_signature(path, windows, 3, backend="pallas_interpret")
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)
    g_jax = jax.grad(lambda p: jnp.sum(
        C.windowed_signature(p, windows, 3, backend="jax") ** 2))(path)
    g_pal = jax.grad(lambda p: jnp.sum(
        C.windowed_signature(p, windows, 3,
                             backend="pallas_interpret") ** 2))(path)
    np.testing.assert_allclose(g_pal, g_jax, rtol=1e-4, atol=1e-6)


def test_windowed_projection_cross_engine(rng):
    from tests.conftest import make_path
    path = jnp.asarray(make_path(rng, 2, 12, 3))
    windows = np.asarray([[0, 6], [3, 12]], np.int32)
    plan = _plan()
    a = C.windowed_projection(path, windows, plan, backend="jax")
    b = C.windowed_projection(path, windows, plan,
                              backend="pallas_interpret")
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


def test_logsignature_cross_engine(rng):
    from tests.conftest import make_path
    path = jnp.asarray(make_path(rng, 2, 9, 3))
    for fn in (C.logsignature, C.logsignature_projected):
        a = fn(path, 3, backend="jax")
        b = fn(path, 3, backend="pallas_interpret")
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)
        ga = jax.grad(lambda p: jnp.sum(fn(p, 3, backend="jax") ** 2))(path)
        gb = jax.grad(lambda p: jnp.sum(
            fn(p, 3, backend="pallas_interpret") ** 2))(path)
        np.testing.assert_allclose(gb, ga, rtol=1e-4, atol=1e-5)


def test_time_parallel_gradients_match():
    x = _incs(40, 2, 13, 3)
    g_plain = jax.grad(lambda z: jnp.sum(
        ops.signature(z, 3, backend="pallas_interpret", batch_tile=8) ** 2))(x)
    g_tp = jax.grad(lambda z: jnp.sum(
        ops.signature(z, 3, backend="pallas_interpret", batch_tile=8,
                      time_chunks=3) ** 2))(x)
    np.testing.assert_allclose(g_tp, g_plain, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# dtype preservation
# ---------------------------------------------------------------------------

def test_float64_dtype_preserved_across_engines():
    try:
        jax.config.update("jax_enable_x64", True)
        x = _incs(50, 2, 7, 3, dtype=np.float64)
        assert x.dtype == jnp.float64
        a = ops.signature(x, 3, backend="jax")
        b = ops.signature(x, 3, backend="pallas_interpret", batch_tile=8)
        assert a.dtype == jnp.float64
        assert b.dtype == jnp.float64  # kernel computes f32, restores dtype
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)
        p = ops.projected(x, _plan(), backend="pallas_interpret",
                          batch_tile=8)
        assert p.dtype == jnp.float64
        g = jax.grad(lambda z: ops.signature(
            z, 3, backend="pallas_interpret", batch_tile=8).sum())(x)
        assert g.dtype == jnp.float64
    finally:
        jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# compiled-Pallas-only twin (runs on a real TPU; interpret twin covers CPU)
# ---------------------------------------------------------------------------

@pytest.mark.tpu
def test_compiled_pallas_grad_matches_jax():
    x = _incs(60, 4, 11, 3)
    a = ops.signature(x, 4, backend="pallas")
    b = ops.signature(x, 4, backend="jax")
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
    g_pal = jax.grad(lambda z: jnp.sum(
        ops.signature(z, 4, backend="pallas") ** 2))(x)
    g_jax = jax.grad(lambda z: jnp.sum(
        ops.signature(z, 4, backend="jax") ** 2))(x)
    np.testing.assert_allclose(g_pal, g_jax, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# hybrid engine exposure: backend="hybrid" cell (dense W_{<=N-1} + top words)
# ---------------------------------------------------------------------------

def _logsig_shape_words(d, N):
    """The §3.3-shaped set: all words below N plus Lyndon words at N."""
    return tuple(C.all_words(d, N - 1) +
                 [w for w in C.lyndon_words(d, N) if len(w) == N])


def test_hybrid_backend_golden_vs_dense_logsig_shape():
    d, N = 3, 4
    plan = make_plan(_logsig_shape_words(d, N), d)
    x = _incs(3, 4, 18, d)
    a = np.asarray(ops.projected(x, plan, backend="hybrid"))
    b = np.asarray(ops.projected(x, plan, backend="jax"))
    np.testing.assert_allclose(a, b, atol=1e-5 * max(np.abs(b).max(), 1.0))


def test_hybrid_backend_golden_arbitrary_mixed_set():
    # requested words at several levels, unsorted, with a duplicate level-N
    words = ((1, 0, 2), (0,), (2, 1), (0, 0, 0), (1,), (1, 0, 2))
    plan = make_plan(words, 3)
    x = _incs(4, 3, 15, 3)
    a = np.asarray(ops.projected(x, plan, backend="hybrid"))
    b = np.asarray(ops.projected(x, plan, backend="jax"))
    assert a.shape == (3, len(words))
    np.testing.assert_allclose(a, b, atol=1e-5 * max(np.abs(b).max(), 1.0))


@pytest.mark.parametrize("backward", ["inverse", "autodiff", "checkpoint"])
def test_hybrid_backend_gradients_match_jax(backward):
    plan = _plan()
    x = _incs(5, 2, 12, 3)
    gh = jax.grad(lambda z: jnp.sum(ops.projected(
        z, plan, backend="hybrid", backward=backward) ** 2))(x)
    gj = jax.grad(lambda z: jnp.sum(ops.projected(
        z, plan, backend="jax", backward="inverse") ** 2))(x)
    np.testing.assert_allclose(np.asarray(gh), np.asarray(gj), rtol=2e-4,
                               atol=2e-5)


def test_hybrid_backend_through_core_and_logsignature():
    d, N = 3, 3
    rng = np.random.default_rng(11)
    path = jnp.asarray(np.cumsum(rng.normal(size=(2, 14, d)) * 0.3,
                                 axis=1).astype(np.float32))
    a = np.asarray(C.projected_signature(path, WORDS, d, backend="hybrid"))
    b = np.asarray(C.projected_signature(path, WORDS, d, backend="jax"))
    np.testing.assert_allclose(a, b, atol=1e-5)
    la = np.asarray(C.logsignature_projected(path, N, backend="hybrid"))
    lb = np.asarray(C.logsignature(path, N))
    np.testing.assert_allclose(la, lb, atol=1e-4 * max(np.abs(lb).max(), 1.0))


def test_hybrid_backend_depth1_and_stream_and_trunc():
    plan1 = make_plan(((0,), (2,)), 3)     # depth 1: falls back to word engine
    x = _incs(6, 2, 9, 3)
    a = np.asarray(ops.projected(x, plan1, backend="hybrid"))
    b = np.asarray(ops.projected(x, plan1, backend="jax"))
    np.testing.assert_allclose(a, b, atol=1e-6)
    with pytest.raises(NotImplementedError):
        ops.projected(x, _plan(), backend="hybrid", stream=True)
    with pytest.raises(ValueError):
        ops.signature(x, 3, backend="hybrid")


def test_hybrid_backend_forward_only():
    plan = _plan()
    x = _incs(7, 3, 11, 3)
    a = np.asarray(ops.projected_forward_only(x, plan, backend="hybrid"))
    b = np.asarray(ops.projected(x, plan, backend="jax"))
    np.testing.assert_allclose(a, b, atol=1e-5)
