"""Word algebra unit + property tests (paper §2.3, Appendix A)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import words as W


# ---------------------------------------------------------------------------
# encoding (Appendix A)
# ---------------------------------------------------------------------------

@given(st.integers(2, 6), st.lists(st.integers(0, 5), min_size=0, max_size=8))
def test_encode_decode_roundtrip(d, letters):
    word = tuple(l % d for l in letters)
    assert W.decode(W.encode(word, d), len(word), d) == word


@given(st.integers(2, 5), st.integers(1, 5), st.data())
def test_encode_preserves_lex_order(d, n, data):
    w1 = tuple(data.draw(st.integers(0, d - 1)) for _ in range(n))
    w2 = tuple(data.draw(st.integers(0, d - 1)) for _ in range(n))
    if w1 < w2:
        assert W.encode(w1, d) < W.encode(w2, d)  # Prop. A.2


@given(st.integers(2, 5), st.data())
def test_concat_prefix_suffix_codes(d, data):
    u = tuple(data.draw(st.integers(0, d - 1))
              for _ in range(data.draw(st.integers(1, 4))))
    v = tuple(data.draw(st.integers(0, d - 1))
              for _ in range(data.draw(st.integers(1, 4))))
    cu, cv = W.encode(u, d), W.encode(v, d)
    cw = W.concat_codes(cu, cv, len(v), d)          # Prop. A.3
    assert cw == W.encode(u + v, d)
    assert W.prefix_code(cw, len(u) + len(v), len(u), d) == cu   # Cor. A.4
    assert W.suffix_code(cw, len(v), d) == cv                    # Cor. A.5


def test_sig_dim_and_offsets():
    assert W.sig_dim(3, 4) == 3 + 9 + 27 + 81
    offs = W.level_offsets(3, 4)
    assert offs[1] == 0 and offs[2] == 3 and offs[3] == 12 and offs[4] == 39
    assert W.flat_index((1, 2), 3) == 3 + 1 * 3 + 2


# ---------------------------------------------------------------------------
# word-set constructors (paper §7)
# ---------------------------------------------------------------------------

def test_all_words_counts():
    assert len(W.all_words(4, 3)) == 4 + 16 + 64


def test_lyndon_counts_match_necklace_formula():
    # Witt formula: L_n(d) = (1/n) sum_{e|n} mu(e) d^{n/e}
    def mobius(n):
        if n == 1:
            return 1
        p, m, r = 2, n, 1
        while p * p <= m:
            if m % p == 0:
                m //= p
                if m % p == 0:
                    return 0
                r = -r
            p += 1
        if m > 1:
            r = -r
        return r

    for d in (2, 3, 5):
        lw = W.lyndon_words(d, 6)
        for n in range(1, 7):
            want = sum(mobius(e) * d ** (n // e)
                       for e in range(1, n + 1) if n % e == 0) // n
            got = sum(1 for w in lw if len(w) == n)
            assert got == want, (d, n, got, want)


def test_lyndon_words_are_lyndon():
    for w in W.lyndon_words(3, 5):
        rotations = [w[i:] + w[:i] for i in range(1, len(w))]
        assert all(w < r for r in rotations), w


@given(st.integers(2, 4), st.lists(
    st.floats(0.5, 3.0, allow_nan=False), min_size=2, max_size=4),
    st.floats(1.0, 5.0, allow_nan=False))
@settings(max_examples=25, deadline=None)
def test_anisotropic_sets_prefix_closed_and_correct(d, gamma, r):
    gamma = gamma[:d] + [1.0] * max(0, d - len(gamma))
    ws = W.anisotropic_words(gamma[:d], r)
    s = set(ws)
    for w in ws:
        assert sum(gamma[i] for i in w) <= r + 1e-9
        for k in range(1, len(w)):
            assert w[:k] in s  # prefix-closed (Def. 3.3)


def test_dag_words_respect_edges():
    ws = W.dag_words([(0, 1), (1, 2)], 3, 3)
    assert (0, 1, 2) in ws and (0, 2) not in ws and (2,) in ws


def test_generated_words_sparse_leadlag():
    from repro.core.transforms import sparse_leadlag_generators
    gens = sparse_leadlag_generators(2)     # d=2 -> alphabet size 4
    ws = W.generated_words(gens, 4)
    # redundancy reduction claim of §8: strictly sparser than truncation
    assert len(ws) < len(W.all_words(4, 4))
    assert (2,) in ws and (0, 2) in ws and (0, 1) not in ws


# ---------------------------------------------------------------------------
# plans & tiling (paper §3.1-3.2)
# ---------------------------------------------------------------------------

@given(st.integers(2, 4), st.data())
@settings(max_examples=30, deadline=None)
def test_plan_invariants(d, data):
    n_words = data.draw(st.integers(1, 12))
    ws = [tuple(data.draw(st.integers(0, d - 1))
                for _ in range(data.draw(st.integers(1, 5))))
          for _ in range(n_words)]
    plan = W.make_plan(ws, d)
    closure = set(plan.closure)
    for w in plan.closure:
        for k in range(1, len(w)):
            assert w[:k] in closure
    # the Horner tables: divisor at step j is 1/(n-j) (paper Alg. 1)
    for r, w in enumerate(plan.closure):
        n = len(w)
        for j in range(n):
            assert plan.inv[r, j] == pytest.approx(1.0 / (n - j))
            assert plan.letters[r, j] == w[j]
        assert plan.emit[r, n - 1] == 1.0


@given(st.integers(2, 3), st.integers(8, 64))
@settings(max_examples=10, deadline=None)
def test_tiled_plan_covers_and_is_prefix_closed(d, max_rows):
    ws = W.all_words(d, 3)
    tp = W.make_tiled_plan(ws, d, max_rows=max_rows)
    covered = set()
    for t in tp.tiles:
        cs = set(t.closure)
        for w in t.closure:
            for k in range(1, len(w)):
                assert w[:k] in cs
        covered.update(t.words)
    assert covered == set(ws)
